"""Engines (paper §5.4/§6): Jacobi, N-body, stencil — inside and outside
networks, against known solutions."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (Collect, Emit, IterativeEngine, MultiCoreEngine,
                        Network, Stencil, StencilEngine, build, rows,
                        run_sequential)


def _jacobi_state(n, rng):
    A = rng.normal(size=(n, n)).astype(np.float32)
    A += n * np.eye(n, dtype=np.float32)  # diagonally dominant (paper §6.2)
    x_true = rng.normal(size=(n,)).astype(np.float32)
    b = A @ x_true
    return {"A": jnp.asarray(A), "b": jnp.asarray(b),
            "x": jnp.zeros(n, jnp.float32)}, x_true


def _jacobi_engine(n, nodes, tol=1e-7):
    def partition(state, lo, size):
        return {"A": rows(state["A"], lo, size),
                "b": rows(state["b"], lo, size),
                "x": state["x"], "lo": lo, "size": size}

    def calculation(part):
        A_, b_, x = part["A"], part["b"], part["x"]
        idx = part["lo"] + jnp.arange(part["size"])
        diag = jax.vmap(lambda r, j: r[j])(A_, idx)
        return (b_ - A_ @ x + diag * rows(x, part["lo"], part["size"])) / diag

    def update(state, new_x):
        return {**state, "x": new_x}

    def error(state, new_x):
        return jnp.max(jnp.abs(new_x - state["x"]))

    return IterativeEngine(partition=partition, calculation=calculation,
                           update=update, error=error, n_rows=n, nodes=nodes,
                           tol=tol)


class TestJacobi:
    def test_converges_to_solution(self, rng):
        n = 32
        state, x_true = _jacobi_state(n, rng)
        eng = _jacobi_engine(n, nodes=4)
        out = jax.jit(eng.apply)(state)
        np.testing.assert_allclose(np.asarray(out["x"]), x_true,
                                   rtol=1e-4, atol=1e-4)

    @pytest.mark.parametrize("nodes", [1, 2, 8])
    def test_partition_count_invariance(self, rng, nodes):
        """Same answer for any node count (paper: partitioning is
        user-visible but result-invariant)."""
        n = 16
        state, x_true = _jacobi_state(n, rng)
        out = jax.jit(_jacobi_engine(n, nodes=nodes).apply)(state)
        np.testing.assert_allclose(np.asarray(out["x"]), x_true,
                                   rtol=1e-4, atol=1e-4)


class TestNBody:
    def _engine(self, n, nodes, iterations, dt=1e-3):
        def partition(state, lo, size):
            return {"pos": state["pos"], "vel": rows(state["vel"], lo, size),
                    "mass": state["mass"], "my_pos": rows(state["pos"], lo,
                                                          size)}

        def calculation(part):
            # acceleration on my partition from ALL bodies (shared read)
            diff = part["pos"][None, :, :] - part["my_pos"][:, None, :]
            r2 = jnp.sum(diff * diff, axis=-1) + 1e-3
            inv_r3 = r2 ** -1.5
            acc = jnp.einsum("ijk,ij,j->ik", diff, inv_r3, part["mass"])
            new_vel = part["vel"] + dt * acc
            return new_vel

        def update(state, new_vel):
            return {**state, "vel": new_vel,
                    "pos": state["pos"] + dt * new_vel}

        return IterativeEngine(partition=partition, calculation=calculation,
                               update=update, n_rows=n, nodes=nodes,
                               iterations=iterations)

    def test_momentum_conserved(self, rng):
        n = 16
        state = {"pos": jnp.asarray(rng.normal(size=(n, 3)),
                                    jnp.float32),
                 "vel": jnp.zeros((n, 3), jnp.float32),
                 "mass": jnp.asarray(rng.random(n) + 0.5, jnp.float32)}
        out = jax.jit(self._engine(n, nodes=4, iterations=10).apply)(state)
        p = np.asarray(jnp.einsum("i,ik->k", state["mass"], out["vel"]))
        # equal & opposite forces: total momentum stays ~0
        assert np.abs(p).max() < 1e-3

    def test_node_invariance(self, rng):
        n = 8
        state = {"pos": jnp.asarray(rng.normal(size=(n, 3)), jnp.float32),
                 "vel": jnp.zeros((n, 3), jnp.float32),
                 "mass": jnp.ones(n, jnp.float32)}
        o1 = jax.jit(self._engine(n, 1, 5).apply)(state)
        o2 = jax.jit(self._engine(n, 4, 5).apply)(state)
        np.testing.assert_allclose(np.asarray(o1["pos"]),
                                   np.asarray(o2["pos"]), rtol=1e-5)


class TestStencilEngine:
    def test_pallas_stage_in_network(self, rng):
        """Paper Listing 17: Emit → grey engine → conv engine → Collect."""
        imgs = [jnp.asarray(rng.normal(size=(32, 32, 3)).astype(np.float32))
                for _ in range(3)]
        kern = jnp.asarray(rng.normal(size=(3, 3)).astype(np.float32))

        def grey(img):
            return jnp.einsum("hwc->hw", img) / 3.0

        net = Network("image")
        net.add(
            Emit(lambda i: imgs[i], name="emit"),
            StencilEngine(functionMethod=grey, name="engine1"),
            StencilEngine(convolutionData=kern, use_pallas=True,
                          name="engine2"),
            Collect(lambda acc, x: acc + jnp.sum(x),
                    init=jnp.asarray(0.0), jit_combine=True, name="collect"),
        )
        seq = run_sequential(net, 3)["collect"]
        par = build(net).run(instances=3)["collect"]
        from repro.kernels.stencil import ref as st_ref
        expect = sum(float(jnp.sum(st_ref.stencil2d(grey(im), kern)))
                     for im in imgs)
        assert float(seq) == pytest.approx(expect, rel=1e-4)
        assert float(par) == pytest.approx(expect, rel=1e-4)


class TestEngineInNetwork:
    def test_multicore_engine_process(self, rng):
        """Paper Listing 15 shape: Emit → MultiCoreEngine → Collect."""
        n = 16
        states = []
        trues = []
        for s in range(2):
            st, xt = _jacobi_state(n, rng)
            states.append(st)
            trues.append(xt)
        eng = _jacobi_engine(n, nodes=2)
        proc = MultiCoreEngine(
            nodes=2, n_rows=n,
            partitionMethod=eng.partition,
            calculationMethod=eng.calculation,
            updateMethod=eng.update, errorMethod=eng.error, tol=1e-7)
        net = Network("jacobi")
        net.add(Emit(lambda i: states[i], name="emit"), proc,
                Collect(lambda acc, st: acc + [np.asarray(st["x"])],
                        init=[], name="collect"))
        out = build(net).run(instances=2)["collect"]
        for got, want in zip(out, trues):
            np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
