"""Sequential oracle ≡ compiled SPMD execution (paper P4), fan/merge
round-trips, logged execution."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import (Collect, DataParallelCollect, Emit,
                        GroupOfPipelineCollects, Network, OnePipelineCollect,
                        TaskParallelOfGroupCollects, Worker, build,
                        run_sequential)
from repro.core.builder import _fan_merge, _fan_split


def _sq(x):
    return x * x


def _inc(x):
    return x + 1.0


def _add(a, x):
    return a + x


def _mk_items(n):
    return lambda i: jnp.asarray(float(i))


class TestOracleEquivalence:
    def test_farm(self):
        net = DataParallelCollect(create=_mk_items(8), function=_sq,
                                  collector=_add, init=jnp.asarray(0.0),
                                  workers=3, jit_combine=True)
        seq = run_sequential(net, 8)["collect"]
        par = build(net).run(instances=8)["collect"]
        assert float(seq) == pytest.approx(float(par))
        assert float(seq) == sum(i * i for i in range(8))

    def test_pipeline(self):
        net = OnePipelineCollect(create=_mk_items(6), stage_ops=[_sq, _inc],
                                 collector=_add, init=jnp.asarray(0.0),
                                 jit_combine=True)
        seq = run_sequential(net, 6)["collect"]
        par = build(net).run(instances=6)["collect"]
        assert float(seq) == pytest.approx(float(par))
        assert float(seq) == sum(i * i + 1 for i in range(6))

    @pytest.mark.parametrize("pattern", ["gop", "pog"])
    def test_composites(self, pattern):
        kw = dict(create=_mk_items(12), stage_ops=[_sq, _inc, _inc],
                  collector=_add, init=jnp.asarray(0.0), jit_combine=True)
        if pattern == "gop":
            net = GroupOfPipelineCollects(groups=3, **kw)
        else:
            net = TaskParallelOfGroupCollects(workers=3, **kw)
        seq = run_sequential(net, 12)["collect"]
        par = build(net).run(instances=12)["collect"]
        assert float(seq) == pytest.approx(float(par))
        assert float(seq) == sum(i * i + 2 for i in range(12))

    def test_gop_equals_pog_numerically(self):
        """The compiled realisations of the two equivalent topologies
        produce identical results (paper §9.2)."""
        kw = dict(create=_mk_items(8), stage_ops=[_sq, _inc],
                  collector=_add, init=jnp.asarray(0.0), jit_combine=True)
        a = build(GroupOfPipelineCollects(groups=2, **kw)).run(instances=8)
        b = build(TaskParallelOfGroupCollects(workers=2, **kw)).run(
            instances=8)
        assert float(a["collect"]) == pytest.approx(float(b["collect"]))

    def test_host_side_collector(self):
        """Non-jittable collector (dict building) folds host-side."""
        net = DataParallelCollect(
            create=_mk_items(5), function=_sq,
            collector=lambda acc, x: {**acc, len(acc): float(x)},
            init={}, workers=2, jit_combine=False)
        out = build(net).run(instances=5)["collect"]
        assert out == {i: float(i * i) for i in range(5)}


class TestFanMerge:
    @settings(max_examples=20, deadline=None)
    @given(n=st.integers(1, 5), k=st.integers(1, 4))
    def test_roundtrip(self, n, k):
        total = n * k
        x = jnp.arange(total * 3, dtype=jnp.float32).reshape(total, 3)
        parts = _fan_split(x, k)
        back = _fan_merge(parts) if k > 1 else parts[0]
        np.testing.assert_array_equal(np.asarray(back), np.asarray(x))

    def test_uneven_split_refused(self):
        from repro.core import NetworkError
        with pytest.raises(NetworkError, match="divisible"):
            _fan_split(jnp.arange(7.0), 2)


class TestLoggedExecution:
    def test_logs_and_bottleneck(self):
        net = DataParallelCollect(create=_mk_items(8), function=_sq,
                                  collector=_add, init=jnp.asarray(0.0),
                                  workers=2, jit_combine=True)
        cn = build(net)
        out = cn.run(instances=8, logged=True)
        assert float(out["collect"]) == sum(i * i for i in range(8))
        stages = {l.stage for l in cn.logs}
        assert "group" in stages and "collect" in stages
        rep = cn.log_report()
        assert "bottleneck" in rep

    def test_netlog_visualisation(self):
        """Paper §13 future work: timeline + topology deduced from the DSL."""
        from repro.core import netlog
        net = DataParallelCollect(create=_mk_items(8), function=_sq,
                                  collector=_add, init=jnp.asarray(0.0),
                                  workers=2, jit_combine=True)
        cn = build(net)
        cn.run(instances=8, logged=True)
        rep = netlog.report(cn)
        assert "bottleneck" in rep and "network" in rep
        assert "spreader/fan" in rep and "reducer/merge" in rep
        assert "█" in rep

    def test_logged_equals_fused(self):
        net = OnePipelineCollect(create=_mk_items(6), stage_ops=[_sq, _inc],
                                 collector=_add, init=jnp.asarray(0.0),
                                 jit_combine=True)
        cn = build(net)
        assert float(cn.run(instances=6)["collect"]) == pytest.approx(
            float(cn.run(instances=6, logged=True)["collect"]))


class TestEmitWithLocal:
    def test_local_state_threads(self):
        from repro.core import EmitWithLocal, AnyFanOne, OneFanAny

        def create(i, local):  # running sum as local state (sieve-like)
            local = local + i
            return jnp.asarray(float(local)), local

        net = Network("loc")
        net.add(EmitWithLocal(create, lambda: 0, name="emit"),
                OneFanAny(name="s"),
                Worker(lambda x: x, name="w"),
                AnyFanOne(name="r"),
                Collect(_add, init=jnp.asarray(0.0), jit_combine=True,
                        name="collect"))
        seq = run_sequential(net, 5)["collect"]
        par = build(net).run(instances=5)["collect"]
        # emitted: 0,1,3,6,10 → sum 20
        assert float(seq) == 20.0 == float(par)
