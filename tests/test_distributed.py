"""Multi-device behaviour, via subprocesses (jax device count is fixed at
first init, so the main pytest process must stay single-device)."""

import json
import os
import subprocess
import sys
import textwrap

import pytest

pytestmark = pytest.mark.slow  # excluded from the fast CI lane

_SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(code: str, devices: int = 8, timeout: int = 560) -> str:
    prog = (
        "import os\n"
        f"os.environ['XLA_FLAGS'] = "
        f"'--xla_force_host_platform_device_count={devices}'\n"
        f"import sys; sys.path.insert(0, {_SRC!r})\n"
        + textwrap.dedent(code))
    r = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                       text=True, timeout=timeout)
    assert r.returncode == 0, f"subprocess failed:\n{r.stderr[-3000:]}"
    return r.stdout


def test_pipeline_parallel_exact():
    out = _run("""
    import jax, jax.numpy as jnp
    from repro.parallel.pipeline import pipeline_forward, split_stages
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((4,), ("stage",))
    L, D = 8, 16
    Ws = jax.random.normal(jax.random.PRNGKey(0), (L, D, D)) * 0.2
    def block_fn(lp, h):
        out, _ = jax.lax.scan(lambda c, w: (jnp.tanh(c @ w), None), h, lp)
        return out
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 4, D))
    seq = block_fn(Ws, x)
    got = pipeline_forward(block_fn, split_stages(Ws, 4), x, mesh=mesh,
                           n_stages=4, n_micro=4)
    print(float(jnp.max(jnp.abs(got - seq))))
    """)
    assert float(out.strip()) == 0.0


def test_int8_ring_allreduce_and_error_feedback():
    out = _run("""
    import jax, jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro.parallel.collectives import ring_allreduce_int8
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((8,), ("dp",))
    g = jax.random.normal(jax.random.PRNGKey(2), (8, 1000)) * 0.01
    def red0(gl):
        r, e = ring_allreduce_int8(gl[0], "dp", 8)
        return r[None], e[None]
    def red(gl, el):
        r, e = ring_allreduce_int8(gl[0], "dp", 8, error=el[0])
        return r[None], e[None]
    from repro.core._jax_compat import shard_map
    red0j = jax.jit(shard_map(red0, mesh=mesh, in_specs=(P("dp"),),
                              out_specs=(P("dp"), P("dp"))))
    redj = jax.jit(shard_map(red, mesh=mesh,
                             in_specs=(P("dp"), P("dp")),
                             out_specs=(P("dp"), P("dp"))))
    exact = jnp.sum(g, axis=0)
    r1, err = red0j(g)
    rel1 = float(jnp.max(jnp.abs(r1[0] - exact)) / jnp.max(jnp.abs(exact)))
    # feed the SAME gradient again with error feedback: residue is re-
    # injected, so the time-averaged estimate improves
    r2, err = redj(g, err)
    avg = (r1[0] + r2[0]) / 2
    rel2 = float(jnp.max(jnp.abs(avg - exact)) / jnp.max(jnp.abs(exact)))
    print(rel1, rel2)
    """)
    rel1, rel2 = map(float, out.split())
    assert rel1 < 0.05  # int8 quantisation error is small
    assert rel2 < rel1  # error feedback reduces the time-averaged error


def test_compiled_farm_uses_devices():
    """The farm pattern with axis sharding really partitions the batch."""
    out = _run("""
    import jax, jax.numpy as jnp
    from repro.core import DataParallelCollect, build
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((8,), ("data",))
    net = DataParallelCollect(
        create=lambda i: jnp.asarray(float(i)),
        function=lambda x: x * x,
        collector=lambda a, x: a + x, init=jnp.asarray(0.0),
        workers=8, axis="data", jit_combine=True)
    cn = build(net, mesh=mesh)
    batch = cn.make_batch(64)
    lowered = cn.lower(batch)
    txt = lowered.compile().as_text()
    out = cn.run(instances=64)
    print(float(out["collect"]), txt.count("all-reduce") > 0)
    """)
    val, has_ar = out.split()
    assert float(val) == sum(i * i for i in range(64))
    assert has_ar == "True"  # the Collect fold psums across shards


def test_reduced_model_dryrun_small_mesh():
    """End-to-end mini dry-run: reduced config, (2,2) mesh, sharded params
    lower+compile and the collective parser finds traffic."""
    out = _run("""
    import jax, jax.numpy as jnp, dataclasses
    from repro.configs import get_config
    from repro.models import Model
    from repro.parallel import sharding as shlib
    from repro.parallel.axes import shard_ctx, ShardingRules
    from repro.train.optimizer import AdamW
    from repro.train.train_loop import make_train_step
    from repro.launch.dryrun import _collective_bytes
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((2, 2), ("data", "model"))
    cfg = dataclasses.replace(get_config("qwen2-0.5b", reduced=True),
                              compute_dtype="float32")
    model = Model(cfg)
    params_sds = jax.eval_shape(model.init,
                                jax.ShapeDtypeStruct((2,), jnp.uint32))
    rules = ShardingRules()
    p_spec = shlib.param_specs(params_sds, mesh, rules)
    p_sh = shlib.to_shardings(p_spec, mesh)
    batch = {"tokens": jax.ShapeDtypeStruct((8, 16), jnp.int32),
             "labels": jax.ShapeDtypeStruct((8, 16), jnp.int32)}
    b_sh = shlib.to_shardings(shlib.batch_specs(batch, mesh, rules), mesh)
    opt = AdamW()
    opt_sds = jax.eval_shape(opt.init, params_sds)
    o_sh = shlib.to_shardings({"m": p_spec, "v": p_spec,
                               "step": jax.sharding.PartitionSpec()}, mesh)
    with shard_ctx(mesh, rules):
        step = make_train_step(model, opt)
        compiled = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh),
                           out_shardings=(p_sh, o_sh, None)).lower(
            params_sds, opt_sds, batch).compile()
    coll, kinds = _collective_bytes(compiled.as_text())
    ma = compiled.memory_analysis()
    print(coll > 0, ma.temp_size_in_bytes > 0)
    """, devices=4)
    assert out.split() == ["True", "True"]


def test_elastic_remesh_checkpoint():
    """A checkpoint written under one mesh restores onto another (the
    elastic-scaling path: pod loss → shrink and continue)."""
    out = _run("""
    import tempfile, numpy as np
    import jax, jax.numpy as jnp
    from repro.configs import get_config
    from repro.models import Model
    from repro.parallel import sharding as shlib
    from repro.train import AdamW, Checkpointer
    from repro.launch.mesh import make_mesh, train_rules
    cfg = get_config("qwen2-0.5b", reduced=True)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rules = train_rules()
    mesh_a = make_mesh((4, 2), ("data", "model"))
    sh_a = shlib.to_shardings(shlib.param_specs(params, mesh_a, rules),
                              mesh_a)
    placed = jax.tree_util.tree_map(jax.device_put, params, sh_a)
    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d)
        ck.save(5, {"params": placed})
        # restore onto a SHRUNK mesh (node loss: 8 -> 4 devices)
        mesh_b = make_mesh((2, 2), ("data", "model"))
        sh_b = shlib.to_shardings(shlib.param_specs(params, mesh_b, rules),
                                  mesh_b)
        step, restored = ck.restore({"params": params},
                                    shardings={"params": sh_b})
        ok = all(np.allclose(np.asarray(a), np.asarray(b)) for a, b in zip(
            jax.tree_util.tree_leaves(params),
            jax.tree_util.tree_leaves(restored["params"])))
        devs = {d2 for l in jax.tree_util.tree_leaves(restored["params"])
                for d2 in l.devices()}
        print(step == 5, ok, len(devs) == 4)
    """)
    assert out.split() == ["True", "True", "True"]


def test_mesh_numerical_invariance():
    """The same train step on a (2,2) mesh and on one device produces the
    same loss/gradients — distribution never changes semantics."""
    out = _run("""
    import jax, jax.numpy as jnp, dataclasses
    from repro.configs import get_config
    from repro.models import Model
    from repro.data import SyntheticLM
    from repro.parallel import sharding as shlib
    from repro.parallel.axes import shard_ctx, ShardingRules
    from repro.launch.mesh import make_mesh, train_rules
    cfg = dataclasses.replace(get_config("qwen2-0.5b", reduced=True),
                              compute_dtype="float32")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    src = SyntheticLM(batch=8, seq=16, vocab=cfg.vocab)
    batch = src.create(0)
    loss_plain, _ = jax.jit(model.loss_fn)(params, batch)
    mesh = make_mesh((2, 2), ("data", "model"))
    rules = train_rules()
    sh = shlib.to_shardings(shlib.param_specs(params, mesh, rules), mesh)
    bsh = shlib.to_shardings(shlib.batch_specs(batch, mesh, rules), mesh)
    with shard_ctx(mesh, rules):
        loss_mesh, _ = jax.jit(model.loss_fn, in_shardings=(sh, bsh))(
            jax.tree_util.tree_map(jax.device_put, params, sh),
            jax.tree_util.tree_map(jax.device_put, batch, bsh))
    print(abs(float(loss_plain) - float(loss_mesh)))
    """, devices=4)
    assert float(out.strip()) < 1e-4


def test_multipod_mesh_axes():
    out = _run("""
    from repro.launch.mesh import make_production_mesh
    m = make_production_mesh(multi_pod=True)
    print(m.axis_names, m.devices.size)
    m1 = make_production_mesh()
    print(m1.axis_names, m1.devices.size)
    """, devices=512)
    lines = out.strip().splitlines()
    assert "('pod', 'data', 'model') 512" in lines[0]
    assert "('data', 'model') 256" in lines[1]
