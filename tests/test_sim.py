"""Fault-injection simulator: the control plane under scheduled failures.

The §6.1.1 obligations, asserted against the REAL ClusterController +
PartitionExecutor stack driven through seeded kill/stall schedules on the
in-process SimTransport (`repro.cluster.sim`), plus the real-pipe
reproduction of the once-bricked mid-recv SIGKILL (the closed ROADMAP open
item).  The fast lane runs a fixed handful of seeds covering every fault
kind; the `slow` sweep and CI's `sim-fuzz` step run the full 50.
"""

import queue
import threading
import time

import pytest

from repro.cluster import ClusterController, ExecConfig, partition
from repro.cluster.sim import (FakeProcess, FaultEvent, FaultSchedule,
                               SimClock, SimLivelock, SimTransport,
                               run_pipe_brick_scenario, run_scenario,
                               sim_farm)


class TestSimMachinery:
    def test_fake_process_lifecycle(self):
        ran = threading.Event()
        p = FakeProcess(target=ran.set, name="t")
        p.start()
        p.join(timeout=5)
        assert ran.is_set() and not p.is_alive() and p.exitcode == 0

    def test_fake_process_kill_mid_park_is_silent(self):
        """A killed host parked on its work queue dies with exitcode -9 and
        reports nothing — SIGKILL semantics, not exception capture."""
        from repro.cluster.sim import SimContext
        q = SimContext.Queue()
        outcomes = []

        def park():
            outcomes.append(q.get())  # blocks forever; kill must unwind

        p = FakeProcess(target=park)
        p.start()
        time.sleep(0.05)
        p.kill()
        p.join(timeout=5)
        assert not p.is_alive() and p.exitcode == -9 and outcomes == []

    def test_clock_budget_is_livelock_check(self):
        clock = SimClock(budget=10)
        with pytest.raises(SimLivelock):
            for _ in range(20):
                clock.tick()

    def test_kill_mid_recv_bricks_channel_and_rebuild_clears(self):
        """The sim models the real mp-queue corpse: a host killed while
        blocked in recv leaves the channel bricked (reads time out empty);
        rebuild_channel replaces the FIFO and clears the brick."""
        sched = FaultSchedule([FaultEvent(host=0, op="recv", at=0,
                                          action="kill", brick=True)])
        sched.arm()
        t = SimTransport(sched, SimClock())
        t.setup([("a", "b")], {("a", "b"): 2})
        ep = t.endpoint(0)
        t.send(("a", "b"), 0, "payload")  # parent sends: no host faults
        died = []

        def victim():
            ep.recv(("a", "b"), 0)

        p = FakeProcess(target=victim)
        p.start()
        p.join(timeout=5)
        died.append(p.exitcode)
        assert died == [-9]
        assert t.bricked_channels([("a", "b")]) == {("a", "b")}
        assert t.rebuild_channel(("a", "b"))
        assert t.bricked_channels([("a", "b")]) == set()

    def test_unrebuildable_brick_reported(self):
        sched = FaultSchedule([FaultEvent(host=0, op="recv", at=0,
                                          action="kill", brick=True)])
        sched.arm()
        t = SimTransport(sched, SimClock(), rebuildable=False)
        t.setup([("a", "b")], {("a", "b"): 2})
        ep = t.endpoint(0)
        p = FakeProcess(target=lambda: ep.recv(("a", "b"), 0))
        p.start()
        p.join(timeout=5)
        assert t.bricked_channels() == {("a", "b")}
        assert not t.rebuild_channel(("a", "b"))

    def test_endpoint_snapshots_queue_map(self):
        """Endpoints copy the queue map at creation like a spawned process
        pickling its args — a rebuilt channel is invisible to them (that is
        why the controller force-restarts live endpoint holders)."""
        t = SimTransport()
        t.setup([("a", "b")], {("a", "b"): 2})
        ep = t.endpoint(0)
        old = ep._queues[("a", "b")]
        assert t.rebuild_channel(("a", "b"))
        assert ep._queues[("a", "b")] is old
        assert t._queues[("a", "b")] is not old

    def test_schedule_fires_once_at_exact_step(self):
        sched = FaultSchedule([FaultEvent(host=1, op="send", at=2,
                                          action="kill")])
        sched.arm()
        assert sched.fire(1, "send", 1) is None      # send#0
        assert sched.fire(1, "recv", 1) is None      # other op: no count
        assert sched.fire(0, "send", 1) is None      # other host
        assert sched.fire(1, "send", 1) is None      # send#1
        ev = sched.fire(1, "send", 1)                # send#2 -> fires
        assert ev is not None and ev.action == "kill"
        assert sched.fire(1, "send", 1) is None      # never twice

    def test_schedule_min_epoch_gates_firing(self):
        sched = FaultSchedule([FaultEvent(host=0, op="recv", at=0,
                                          action="kill", min_epoch=2)])
        sched.arm()
        assert sched.fire(0, "recv", 1) is None  # epoch 1: held back
        # NOTE: the counter advanced; at=0 only matches the first op, so
        # a min_epoch event is armed against the post-recovery stream
        sched2 = FaultSchedule([FaultEvent(host=0, op="recv", at=0,
                                           action="kill", min_epoch=2)])
        sched2.arm()
        assert sched2.fire(0, "recv", 2) is not None

    def test_disarmed_schedule_never_fires(self):
        sched = FaultSchedule([FaultEvent(host=0, op="recv", at=0,
                                          action="kill")])
        assert sched.fire(0, "recv", 1) is None

    def test_sim_transport_epoch_protocol_is_production_code(self):
        """The sim channels run the unmodified _QueueTransport protocol:
        stale epochs and replayed duplicates drop."""
        t = SimTransport()
        t.setup([("a", "b")], {("a", "b"): 8})
        t.send(("a", "b"), 0, "old")
        t.set_epoch(2)
        t.send(("a", "b"), 0, "dup")
        t.send(("a", "b"), 1, "current")
        assert t.recv(("a", "b"), 1) == "current"


class TestSimScenarios:
    """Fixed seeds covering every fault kind (found by inspecting the
    seeded generator — cheap representatives of CI's 50-seed sweep)."""

    @pytest.mark.parametrize("seed", [1, 2, 3, 4, 8])
    def test_fixed_seed_scenarios_green(self, seed):
        r = run_scenario(seed)
        assert r.ok, "\n".join(r.failures)

    def test_fixed_seeds_cover_every_fault_kind(self):
        """The five fast-lane seeds were picked to hit all five scenario
        kinds; pin that so a generator change can't silently shrink
        coverage."""
        import random

        from repro.cluster.sim import sim_pipeline
        kinds = set()
        for seed in (1, 2, 3, 4, 8):
            rng = random.Random(seed)
            if rng.choice(("farm", "pipeline")) == "farm":
                net = sim_farm(8, rng.choice((2, 3)))
            else:
                net = sim_pipeline(8)
            plan = partition(net, hosts=rng.choice((2, 3)))
            kinds.add(FaultSchedule.random(rng, plan).kind)
        assert kinds == {"kill", "stall", "double-kill",
                         "kill-during-recovery", "ctrl-step-kill"}

    def test_double_kill_replay_never_resurrects_stale_results(self):
        """Regression for the bug this harness found: a replay participant
        killed again mid-replay must NOT be backfilled from the failed
        batch's ok_cache (its result there was produced under the OLD
        partition) — seed 2 is the double-kill interleaving that caught
        it (empty merged result)."""
        r = run_scenario(2)
        assert r.ok, "\n".join(r.failures)
        assert r.recoveries >= 1

    @pytest.mark.slow
    def test_seeded_sweep(self):
        """The full CI sim-fuzz sweep, in-suite for the slow lane."""
        bad = []
        for seed in range(50):
            r = run_scenario(seed)
            if not r.ok:
                bad.append(r.describe())
        assert not bad, "\n".join(bad)


class TestRouteAroundUnrebuildableBrick:
    def test_rebalance_fallback_forgets_bricked_fifo(self):
        """An unrebuildable bricked FIFO with survivors: the auto-fallback
        rebalance must FORGET the dead queue (reconfigure would otherwise
        reuse it for an unchanged (src, dst) key and wedge the relocated
        consumer) and recover bit-identically."""
        from repro.core import run_sequential

        instances = 8
        factory = (sim_farm, (instances, 2))
        net = factory[0](*factory[1])
        plan = partition(net, hosts=2)
        consumer = plan.assignment["collect"]
        (c,) = plan.cut
        chan = (c.src, c.dst)
        sched = FaultSchedule([FaultEvent(host=consumer, op="recv", at=0,
                                          action="kill", brick=True)])
        t = SimTransport(sched, SimClock(), rebuildable=False)
        t.recv_timeout_s = 2.0  # the wedged producer errs fast
        oracle = float(run_sequential(net, instances)["collect"])
        ctrl = ClusterController(net, plan, ExecConfig(microbatch_size=2),
                                 t, factory, 30.0)
        ctrl.poll_s = 0.05
        try:
            ctrl.start()
            t.track_hosts(ctrl._procs)
            old_q = t._queues[chan]
            sched.arm()
            from repro.cluster.runtime import ClusterError
            import pytest as _pytest
            with _pytest.raises(ClusterError):
                ctrl.run_batch(instances)
            rec = ctrl.recover(mode="restart")  # auto-falls-back
            assert float(rec["collect"]) == oracle
            (ev,) = ctrl.events
            assert ev.auto_mode and "rebalance" in ev.auto_mode
            assert ev.bricked == [f"{chan[0]}->{chan[1]}"]
            # the dead FIFO was forgotten, not reused, wherever the
            # channel survived the rebalance
            assert t._queues.get(chan) is not old_q
            assert t.bricked_channels() == set()
        finally:
            ctrl.close()


class TestTimeoutPropagation:
    def test_recv_timeout_override_reaches_endpoints(self):
        """An instance-level recv_timeout_s override must ship with the
        endpoints spawned workers receive, or shrinking the knob only
        shrinks controller-side waits (review finding)."""
        from repro.cluster.transport import (MultiProcessPipe,
                                             SharedMemoryRing)
        for t in (MultiProcessPipe(), SharedMemoryRing()):
            try:
                t.recv_timeout_s = 7.5
                assert t.endpoint(0).recv_timeout_s == 7.5
            finally:
                t.close()


class TestUnrecoverableRefusal:
    def test_all_dead_unrebuildable_brick_refuses_cleanly(self):
        """Every host dead + a bricked FIFO the transport cannot rebuild:
        recovery is impossible by construction, and the controller must say
        so in bounded time (found by the simulator as an infinite
        rebalance loop)."""
        from repro.core.dataflow import NetworkError
        from repro.cluster.runtime import ClusterError

        instances = 8
        factory = (sim_farm, (instances, 2))
        net = factory[0](*factory[1])
        plan = partition(net, hosts=2)
        consumer = plan.assignment["collect"]
        others = [h for h in plan.hosts() if h != consumer]
        sched = FaultSchedule(
            [FaultEvent(host=consumer, op="recv", at=0, action="kill",
                        brick=True)]
            + [FaultEvent(host=h, op="park", at=0, action="kill")
               for h in others])
        t = SimTransport(sched, SimClock(), rebuildable=False)
        ctrl = ClusterController(net, plan, ExecConfig(microbatch_size=2),
                                 t, factory, 30.0)
        ctrl.poll_s = 0.05
        try:
            ctrl.start()
            t.track_hosts(ctrl._procs)
            sched.arm()
            with pytest.raises(ClusterError):
                ctrl.run_batch(instances)
            with pytest.raises(NetworkError,
                               match="cannot be recovered"):
                ctrl.recover()
        finally:
            ctrl.close()


@pytest.mark.slow
class TestRealPipeBrick:
    def test_pipe_brick_scenario_recovers_bit_identically(self):
        """The once-bricked ROADMAP scenario on the REAL pipe transport:
        SIGKILL mid-recv leaves a corpse holding the mp queue's reader
        lock; recover() must detect it, rebuild the FIFO, force-restart
        the live producer, and replay bit-identically.  Also gated by CI's
        sim-fuzz step (`python -m repro.cluster.sim --pipe-brick`)."""
        r = run_pipe_brick_scenario(timeout_s=20.0)
        assert r.ok, "\n".join(r.failures)
