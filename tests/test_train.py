"""Training substrate: optimizer, grad accumulation, checkpoint/restart,
fault tolerance, data pipeline."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data import Prefetcher, SyntheticLM
from repro.models import Model
from repro.train import (AdamW, Checkpointer, FaultInjector,
                         FaultTolerantRunner, cosine_warmup, make_train_step,
                         train)
from repro.train.optimizer import clip_by_global_norm, global_norm

pytestmark = pytest.mark.slow  # excluded from the fast CI lane


class TestOptimizer:
    def test_quadratic_convergence(self):
        opt = AdamW(lr=0.1, weight_decay=0.0)
        params = {"w": jnp.asarray([5.0, -3.0])}
        state = opt.init(params)
        for _ in range(200):
            g = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
            params, state, _ = opt.update(g, state, params)
        assert float(jnp.max(jnp.abs(params["w"]))) < 1e-2

    def test_clip_by_global_norm(self):
        tree = {"a": jnp.ones(4) * 10.0}
        clipped, norm = clip_by_global_norm(tree, 1.0)
        assert float(norm) == pytest.approx(20.0)
        assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-4)

    def test_cosine_warmup_shape(self):
        lr = cosine_warmup(1.0, warmup=10, total=100)
        assert float(lr(0)) == 0.0
        assert float(lr(10)) == pytest.approx(1.0)
        assert float(lr(100)) == pytest.approx(0.1, rel=1e-2)
        assert float(lr(55)) < float(lr(20))


class TestTrainStep:
    def test_grad_accum_equivalence(self, key):
        """accum=2 over the same global batch ≈ accum=1 (same update)."""
        cfg = get_config("qwen2-0.5b", reduced=True)
        model = Model(cfg)
        params = model.init(key)
        opt = AdamW(lr=1e-3)
        src = SyntheticLM(batch=8, seq=16, vocab=cfg.vocab)
        batch = src.create(0)
        s1 = make_train_step(model, opt, grad_accum=1)
        s2 = make_train_step(model, opt, grad_accum=2)
        p1, _, m1 = jax.jit(s1)(params, opt.init(params), batch)
        p2, _, m2 = jax.jit(s2)(params, opt.init(params), batch)
        d = max(float(jnp.max(jnp.abs(a - b)))
                for a, b in zip(jax.tree_util.tree_leaves(p1),
                                jax.tree_util.tree_leaves(p2)))
        assert d < 5e-5, f"accum changes update: {d}"

    def test_loss_chunk_equivalence(self, key):
        """Chunked CE (the §Perf memory lever) is numerically identical."""
        import dataclasses
        cfg = get_config("qwen2-0.5b", reduced=True)
        cfg2 = dataclasses.replace(cfg, loss_chunk=8)
        m1, m2 = Model(cfg), Model(cfg2)
        params = m1.init(key)
        src = SyntheticLM(batch=4, seq=32, vocab=cfg.vocab)
        batch = src.create(0)
        l1, _ = jax.jit(m1.loss_fn)(params, batch)
        l2, _ = jax.jit(m2.loss_fn)(params, batch)
        assert abs(float(l1) - float(l2)) < 1e-5
        g1 = jax.grad(lambda p: m1.loss_fn(p, batch)[0])(params)
        g2 = jax.grad(lambda p: m2.loss_fn(p, batch)[0])(params)
        d = max(float(jnp.max(jnp.abs(a - b))) for a, b in zip(
            jax.tree_util.tree_leaves(g1), jax.tree_util.tree_leaves(g2)))
        assert d < 1e-5, f"chunked grads diverge: {d}"

    def test_loss_decreases(self, key):
        cfg = get_config("qwen2-0.5b", reduced=True)
        model = Model(cfg)
        src = SyntheticLM(batch=8, seq=32, vocab=cfg.vocab)
        res = train(model, src, steps=40, opt=AdamW(lr=1e-2), key=key,
                    log_every=1)
        losses = [h["loss"] for h in res["history"]]
        first = sum(losses[:5]) / 5
        last = sum(losses[-5:]) / 5  # step noise: compare window means
        assert last < first - 0.25, (first, last)


class TestCheckpoint:
    def test_roundtrip_exact(self, key):
        tree = {"a": jnp.arange(12.0).reshape(3, 4),
                "b": {"c": jnp.asarray([1, 2, 3], jnp.int32)}}
        with tempfile.TemporaryDirectory() as d:
            ck = Checkpointer(d)
            ck.save(7, tree)
            step, restored = ck.restore(tree)
            assert step == 7
            for x, y in zip(jax.tree_util.tree_leaves(tree),
                            jax.tree_util.tree_leaves(restored)):
                np.testing.assert_array_equal(np.asarray(x), np.asarray(y))

    def test_latest_pointer_and_gc(self):
        tree = {"x": jnp.zeros(3)}
        with tempfile.TemporaryDirectory() as d:
            ck = Checkpointer(d, keep=2)
            for s in (1, 2, 3, 4):
                ck.save(s, tree)
            assert ck.latest_step() == 4
            steps = sorted(x for x in os.listdir(d) if x.startswith("step_"))
            assert len(steps) == 2  # GC kept the last two

    def test_async_save(self):
        tree = {"x": jnp.ones(100)}
        with tempfile.TemporaryDirectory() as d:
            ck = Checkpointer(d, async_save=True)
            ck.save(1, tree)
            ck.wait()
            assert ck.latest_step() == 1

    def test_structure_mismatch_refused(self):
        with tempfile.TemporaryDirectory() as d:
            ck = Checkpointer(d)
            ck.save(1, {"a": jnp.zeros(3)})
            with pytest.raises(AssertionError, match="structure mismatch"):
                ck.restore({"a": jnp.zeros(3), "b": jnp.zeros(2)})

    def test_corrupt_latest_falls_back_to_previous_step(self):
        """A torn write (process killed mid-save) must not take restore()
        down with it: the truncated latest step is skipped and the
        previous complete step restores."""
        like = {"x": jnp.zeros(4)}
        with tempfile.TemporaryDirectory() as d:
            ck = Checkpointer(d, keep=3)
            ck.save(1, {"x": jnp.arange(4.0)})
            ck.save(2, {"x": jnp.arange(4.0) * 10})
            # simulate the mid-write kill: the renamed step_00000002 exists
            # but one leaf blob is truncated to garbage
            leaf = os.path.join(d, "step_00000002", "leaf_00000.npy")
            with open(leaf, "wb") as f:
                f.write(b"\x93NUMPY")  # header cut short
            step, restored = ck.restore(like)
            assert step == 1
            np.testing.assert_array_equal(np.asarray(restored["x"]),
                                          np.arange(4.0))
            # an explicit step= stays strict: the caller asked for exactly
            # that snapshot, so the corruption must surface
            with pytest.raises(Exception):
                ck.restore(like, step=2)

    def test_tmp_dir_from_killed_write_is_invisible(self):
        """A kill BEFORE the atomic rename leaves only step_X.tmp — which
        neither restore() nor steps_on_disk() may see."""
        like = {"x": jnp.zeros(2)}
        with tempfile.TemporaryDirectory() as d:
            ck = Checkpointer(d)
            ck.save(5, {"x": jnp.asarray([1.0, 2.0])})
            os.makedirs(os.path.join(d, "step_00000006.tmp"))
            assert ck.steps_on_disk() == [5]
            step, restored = ck.restore(like)
            assert step == 5
            np.testing.assert_array_equal(np.asarray(restored["x"]),
                                          [1.0, 2.0])

    def test_all_steps_corrupt_reraises(self):
        like = {"x": jnp.zeros(2)}
        with tempfile.TemporaryDirectory() as d:
            ck = Checkpointer(d)
            ck.save(1, {"x": jnp.ones(2)})
            leaf = os.path.join(d, "step_00000001", "leaf_00000.npy")
            with open(leaf, "wb") as f:
                f.write(b"junk")
            with pytest.raises(Exception):
                ck.restore(like)


class TestFaultTolerance:
    def test_injected_failures_recovered(self, key):
        cfg = get_config("qwen2-0.5b", reduced=True)
        model = Model(cfg)
        opt = AdamW(lr=1e-3)
        src = SyntheticLM(batch=4, seq=16, vocab=cfg.vocab)
        params = model.init(key)
        state = {"params": params, "opt_state": opt.init(params)}
        sfj = jax.jit(make_train_step(model, opt))

        def step_fn(i, st):
            b = src.create(i)
            p, o, _ = sfj(st["params"], st["opt_state"], b)
            return {"params": p, "opt_state": o}

        with tempfile.TemporaryDirectory() as d:
            runner = FaultTolerantRunner(Checkpointer(d), max_restarts=3)
            inj = FaultInjector(fail_at=(4, 9))
            final = runner.run(total_steps=12, state=state, step_fn=step_fn,
                               save_every=3, injector=inj)
            assert runner.restarts == 2
            # deterministic data ⇒ final state equals a clean 12-step run
            clean = {"params": params, "opt_state": opt.init(params)}
            for i in range(12):
                clean = step_fn(i, clean)
            d_max = max(float(jnp.max(jnp.abs(a - b))) for a, b in zip(
                jax.tree_util.tree_leaves(final["params"]),
                jax.tree_util.tree_leaves(clean["params"])))
            assert d_max < 1e-6, "restart-recovered run diverges"

    def test_exceeding_restarts_raises(self, key):
        with tempfile.TemporaryDirectory() as d:
            runner = FaultTolerantRunner(Checkpointer(d), max_restarts=1)

            def bad_step(i, st):
                raise RuntimeError("permafail")

            with pytest.raises(RuntimeError, match="max_restarts"):
                runner.run(total_steps=3, state={"x": jnp.zeros(1)},
                           step_fn=bad_step, save_every=1)


class TestDataPipeline:
    def test_synthetic_deterministic(self):
        src = SyntheticLM(batch=2, seq=8, vocab=100, seed=3)
        a = src.create(5)
        b = src.create(5)
        np.testing.assert_array_equal(np.asarray(a["tokens"]),
                                      np.asarray(b["tokens"]))
        # labels are next-token shifted
        full = SyntheticLM(batch=2, seq=8, vocab=100, seed=3)
        c = full.create(5)
        np.testing.assert_array_equal(np.asarray(c["labels"][:, :-1]),
                                      np.asarray(c["tokens"][:, 1:]))

    def test_prefetcher_order_and_ut(self):
        src = SyntheticLM(batch=1, seq=4, vocab=50)
        pf = Prefetcher(src, depth=2, n_steps=5)
        steps = [s for s, _ in pf]
        assert steps == [0, 1, 2, 3, 4]  # ordered, then UT terminates
