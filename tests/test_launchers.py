"""CLI launcher integration tests (subprocess; reduced configs)."""

import os
import subprocess
import sys

import pytest

pytestmark = pytest.mark.slow  # excluded from the fast CI lane

_ENV = {**os.environ,
        "PYTHONPATH": os.path.join(os.path.dirname(__file__), "..", "src")}


def _run(args, timeout=560):
    r = subprocess.run([sys.executable, "-m"] + args, capture_output=True,
                       text=True, timeout=timeout, env=_ENV,
                       cwd=os.path.join(os.path.dirname(__file__), ".."))
    assert r.returncode == 0, r.stderr[-2000:]
    return r.stdout


def test_train_cli():
    out = _run(["repro.launch.train", "--arch", "qwen2-0.5b", "--reduced",
                "--steps", "8", "--batch", "4", "--seq", "32"])
    assert "network train[qwen2-0.5b] verified" in out
    assert "loss" in out


def test_serve_cli():
    out = _run(["repro.launch.serve", "--arch", "qwen2-0.5b", "--reduced",
                "--requests", "4", "--slots", "2", "--max-new", "4"])
    assert "4 requests" in out
    assert "tok/s" in out


def test_dryrun_cli_single_cell():
    env = dict(_ENV)
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch",
         "whisper-tiny", "--shape", "decode_32k", "--mesh", "single"],
        capture_output=True, text=True, timeout=560, env=env,
        cwd=os.path.join(os.path.dirname(__file__), "..")).stdout
    assert "whisper-tiny × decode_32k × 16x16" in out
    assert "flops/dev" in out
