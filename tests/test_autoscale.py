"""Load-driven autoscaling (ROADMAP item 1): the AutoscalePolicy's
hysteresis, the Autoscaler driving a live deployment through epoch-bumped
reconfigures, and the telemetry bugs the policy's signals exposed —
ghost host rows after a replan, capacity-0 channels silently dropped
from occupancy, per-batch samples diluted by plan-total counters, and
dangling channel keys leaking into the bytes/s ledger forever.
"""

import types

import jax.numpy as jnp
import numpy as np
import pytest

from repro.cluster import (AutoscalePolicy, Autoscaler, ClusterDeployment,
                           partition)
from repro.cluster.autoscale import host_depths
from repro.core import OnePipelineCollect, run_sequential
from repro.core import trace as _trace
from repro.core.dataflow import NetworkError


def _pipeline_factory():
    return OnePipelineCollect(
        create=lambda i: jnp.asarray(float(i)),
        stage_ops=[lambda x: x * x, lambda x: x + 1.0,
                   lambda x: x * 2.0, lambda x: x - 3.0],
        collector=lambda a, x: a + x, init=jnp.asarray(0.0),
        jit_combine=True)


def _snap(*, occ=None, stall=None, tps=None, walls=None, epoch=1):
    s = _trace.MetricsSnapshot(epoch=epoch)
    s.occupancy.update(occ or {})
    s.stall_rate.update(stall or {})
    s.throughput.update(tps or {})
    s.batch_wall_s.update(walls or {})
    return s


# ==========================================================================
# Policy hysteresis (pure unit: snapshots in, decisions out)
# ==========================================================================

class TestPolicyHysteresis:
    def test_pressure_must_sustain_before_firing(self):
        pol = AutoscalePolicy(high_occupancy=0.8, sustain=3, cooldown=0,
                              max_hosts=4)
        hot = _snap(occ={"a->b": 0.9})
        assert pol.decide(hot, 2) is None
        assert pol.decide(hot, 2) is None
        action, victim, reason = pol.decide(hot, 2)
        assert action == "add_host" and victim is None
        assert "occupancy" in reason

    def test_transient_resets_the_streak(self):
        pol = AutoscalePolicy(high_occupancy=0.8, sustain=2, cooldown=0)
        hot, cool = _snap(occ={"a->b": 0.9}), _snap(occ={"a->b": 0.1})
        assert pol.decide(hot, 2) is None
        assert pol.decide(cool, 2) is None  # streak broken
        assert pol.decide(hot, 2) is None   # back to 1, not 2
        assert pol.decide(hot, 2) is not None

    def test_cooldown_holds_even_under_pressure(self):
        pol = AutoscalePolicy(high_occupancy=0.8, sustain=1, cooldown=3,
                              max_hosts=8)
        hot = _snap(occ={"a->b": 0.95})
        assert pol.decide(hot, 2) is not None
        for _ in range(3):
            assert pol.decide(hot, 2) is None  # cooling down
        assert pol.decide(hot, 2) is not None

    def test_bounds_veto_at_decision_time(self):
        pol = AutoscalePolicy(high_occupancy=0.8, sustain=1, cooldown=0,
                              min_hosts=2, max_hosts=2)
        assert pol.decide(_snap(occ={"a->b": 0.95}), 2) is None
        pol2 = AutoscalePolicy(imbalance_ratio=2.0, sustain=1, cooldown=0,
                               min_hosts=2)
        skewed = _snap(tps={0: 100.0, 1: 10.0})
        assert pol2.decide(skewed, 2) is None  # n == min_hosts

    def test_unknown_capacity_counts_as_saturated(self):
        """occupancy=None (capacity-0 channel) is suspect, not invisible:
        it must count as full pressure, not be skipped."""
        pol = AutoscalePolicy(high_occupancy=0.9, sustain=1, cooldown=0)
        decision = pol.decide(_snap(occ={"a->b": None}), 2)
        assert decision is not None and decision[0] == "add_host"

    def test_wall_target_fires_pressure(self):
        pol = AutoscalePolicy(high_occupancy=2.0, high_stall_rate=1e9,
                              high_batch_wall_s=0.5, sustain=1, cooldown=0)
        decision = pol.decide(_snap(walls={0: 0.7}), 2)
        assert decision is not None and decision[0] == "add_host"
        assert "batch wall" in decision[2]

    def test_scale_down_disabled_without_latency_budget(self):
        """Drained queues alone are what idle looks like — without
        low_batch_wall_s the policy must never shrink."""
        pol = AutoscalePolicy(sustain=1, cooldown=0, min_hosts=1)
        idle = _snap(occ={"a->b": 0.0}, walls={0: 0.001, 1: 0.001})
        for _ in range(5):
            assert pol.decide(idle, 3) is None
        pol2 = AutoscalePolicy(sustain=1, cooldown=0, min_hosts=1,
                               low_batch_wall_s=0.01)
        decision = pol2.decide(idle, 3)
        assert decision is not None and decision[0] == "remove_host"

    def test_imbalance_gated_by_min_batch_wall(self):
        """Per-host rates over a near-instant batch are noise: the skew
        signal must not fire below min_batch_wall_s."""
        pol = AutoscalePolicy(imbalance_ratio=2.0, min_batch_wall_s=0.05,
                              sustain=1, cooldown=0, min_hosts=1)
        noise = _snap(tps={0: 100.0, 1: 10.0}, walls={0: 0.001, 1: 0.001})
        assert pol.decide(noise, 3) is None
        real = _snap(tps={0: 100.0, 1: 10.0}, walls={0: 0.1, 1: 0.1})
        decision = pol.decide(real, 3)
        assert decision is not None and decision[0] == "migrate"

    def test_victim_is_most_upstream_of_slow_set(self):
        """Bounded channels throttle everything downstream of a straggler
        to its pace, so the raw items/s minimum is the innocent tail —
        the victim must be the most upstream slow host."""
        pol = AutoscalePolicy(imbalance_ratio=1.5, sustain=1, cooldown=0,
                              min_hosts=1)
        snap = _snap(tps={0: 100.0, 1: 40.0, 2: 35.0},
                     walls={0: 0.1, 1: 0.2, 2: 0.21})
        action, victim, _ = pol.decide(snap, 3,
                                       host_depth={0: 0, 1: 1, 2: 2})
        assert action == "migrate"
        assert victim == 1  # not host 2, the throttled tail

    def test_host_depths_from_plan(self):
        plan = partition(_pipeline_factory(), hosts=3)
        depths = host_depths(plan)
        emit_host = plan.assignment["emit"]
        collect_host = plan.assignment["collect"]
        assert depths[emit_host] == 0
        assert depths[collect_host] == max(depths.values())


# ==========================================================================
# The telemetry bugs the policy exposed (satellite regressions)
# ==========================================================================

class TestMetricsRegressions:
    def test_replan_prunes_ghost_host_rows(self):
        """Scale 3 -> 2: the dropped host's _last_reports row must leave
        metrics() with the epoch bump — a policy polling throughput must
        never average in a host the plan no longer has."""
        net = _pipeline_factory()
        with ClusterDeployment(net, hosts=3, transport="inprocess",
                               microbatch_size=2) as dep:
            dep.run(instances=8)
            assert set(dep.metrics().throughput) == {0, 1, 2}
            dep.reconfigure(hosts=2)
            ghost = set(dep.metrics().throughput) - set(
                dep.controller.plan.hosts())
            assert not ghost, f"ghost host rows: {ghost}"

    def test_zero_capacity_channel_surfaces_as_none(self):
        """A channel whose capacity reads 0 is exactly the one a scaling
        policy must see: occupancy=None (unknown), raw depth still in
        queue_depths — not silently dropped."""
        net = _pipeline_factory()
        with ClusterDeployment(net, hosts=2, transport="inprocess",
                               microbatch_size=2) as dep:
            dep.run(instances=8)
            ctrl = dep.controller
            (chan,) = ctrl.transport.channel_depths().keys()
            key = f"{chan[0]}->{chan[1]}"
            ctrl.transport.channel_capacities = lambda: {chan: 0}
            snap = dep.metrics()
            assert key in snap.occupancy and snap.occupancy[key] is None
            assert key in snap.queue_depths
            # and a transient depth > capacity clamps to 1.0
            ctrl.transport.channel_capacities = lambda: {chan: 2}
            ctrl.transport.channel_depths = lambda: {chan: 5}
            snap = dep.metrics()
            assert snap.occupancy[key] == 1.0
            assert snap.queue_depths[key] == 5  # raw depth, unclamped

    def test_metrics_sample_reports_progress_not_plan(self):
        """StreamStats presets n_items/n_chunks to the PLAN totals when a
        run starts, so sampling them reports full throughput for work a
        stalled host never finished.  The sample must come from the
        retired-progress counters, rebased at each serve call."""
        from repro.cluster.runtime import PartitionExecutor
        stats = types.SimpleNamespace(n_items=100, n_chunks=50,
                                      chunks_done=10, items_done=20,
                                      stalls=4)
        fake = types.SimpleNamespace(stats=stats, _sample_base=(0, 0, 0),
                                     sent_bytes={}, recv_bytes={})
        m = PartitionExecutor.metrics_sample(fake, 2.0)
        assert m["items_per_s"] == pytest.approx(10.0)  # 20/2s, not 100/2s
        assert m["stalls_per_chunk"] == pytest.approx(0.4)
        # a resume rebases: only the tail since the stall is billed
        fake._sample_base = (10, 20, 4)
        stats.chunks_done, stats.items_done, stats.stalls = 50, 100, 5
        m = PartitionExecutor.metrics_sample(fake, 1.0)
        assert m["items_per_s"] == pytest.approx(80.0)
        assert m["stalls_per_chunk"] == pytest.approx(1 / 40)

    def test_warm_batches_report_live_throughput(self):
        """Regression for the delta-of-presets bug: warm batches (same
        plan, fresh stats) must report this batch's real rate, not 0."""
        net = _pipeline_factory()
        with ClusterDeployment(net, hosts=2, transport="inprocess",
                               microbatch_size=2) as dep:
            for _ in range(3):
                dep.run(instances=8)
                snap = dep.metrics()
                assert snap.throughput and all(
                    v > 0 for v in snap.throughput.values()), snap.describe()
                assert all(v > 0 for v in snap.batch_wall_s.values())

    def test_reconfigure_prunes_dangling_channel_keys(self):
        """A _cum_chan key whose endpoint processes the net no longer has
        must not leak into bytes_per_s forever; a channel a replan merely
        stopped cutting keeps its lifetime history (it can be re-cut)."""
        net = _pipeline_factory()
        with ClusterDeployment(net, hosts=2, transport="inprocess",
                               microbatch_size=2) as dep:
            dep.run(instances=8)
            ctrl = dep.controller
            live_keys = set(ctrl._cum_chan)
            assert live_keys
            ctrl._cum_chan["ghost->nowhere"] = (4096, 1.0)
            dep.reconfigure(hosts=3)
            snap = dep.metrics()
            assert "ghost->nowhere" not in snap.bytes_per_s
            for k in live_keys:  # real channels keep their lifetime rate
                assert snap.bytes_per_s.get(k, 0) > 0


# ==========================================================================
# The Autoscaler driving a live deployment
# ==========================================================================

class TestAutoscalerIntegration:
    def test_add_host_is_epoch_bumped_reconfigure(self):
        """A fired decision lands as an ordinary reconfigure: epoch bump,
        check_redeployment re-proof, auto_mode annotation — and the next
        batch is still bit-identical to the sequential oracle."""
        net = _pipeline_factory()
        seq = float(run_sequential(net, 8)["collect"])
        policy = AutoscalePolicy(high_occupancy=2.0, high_stall_rate=1e9,
                                 high_batch_wall_s=1e-9,  # any batch trips
                                 sustain=1, cooldown=2,
                                 min_hosts=2, max_hosts=3)
        with ClusterDeployment(net, hosts=2, transport="inprocess",
                               microbatch_size=2,
                               autoscale=policy) as dep:
            out0 = dep.run(instances=8)  # poll fires after this batch
            assert float(np.asarray(out0["collect"])) == seq
            events = dep.autoscale_events
            assert len(events) == 1 and events[0].executed
            ev = events[0]
            assert ev.action == "add_host"
            assert ev.hosts_from == 2 and ev.hosts_to == 3
            assert ev.event.refined is True
            assert ev.event.auto_mode.startswith("autoscale add_host")
            assert dep.epoch == 2
            assert len(dep.controller.plan.hosts()) == 3
            out1 = dep.run(instances=8)
            assert float(np.asarray(out1["collect"])) == seq
            assert "autoscale add_host" in ev.describe()

    def test_veto_is_recorded_and_cooldown_prevents_refire(self):
        """A decision the deployment cannot execute is recorded as vetoed
        — and the policy's cooldown already started, so the impossible
        decision does not re-fire every poll."""
        net = _pipeline_factory()
        policy = AutoscalePolicy(high_occupancy=2.0, high_stall_rate=1e9,
                                 high_batch_wall_s=1e-9, sustain=1,
                                 cooldown=2, min_hosts=2, max_hosts=3)
        with ClusterDeployment(net, hosts=2, transport="inprocess",
                               microbatch_size=2) as dep:
            scaler = Autoscaler(dep, policy)

            def refuse(**kw):
                raise NetworkError("scale-up refused for the test")

            dep.controller.reconfigure = refuse
            dep.run(instances=8)
            ev = scaler.poll()
            assert ev is not None and not ev.executed
            assert "refused" in ev.vetoed
            assert "vetoed" in ev.describe()
            assert scaler.actions == []
            assert scaler.poll() is None  # cooling down, no re-fire
            assert dep.epoch == 1  # nothing executed

    def test_migration_evacuates_victim(self):
        """A forced migrate decision replans the victim's processes onto
        the survivors through reconfigure(plan=...) — same epoch-bump
        contract, victim gone from the new plan."""
        net = _pipeline_factory()
        seq = float(run_sequential(net, 8)["collect"])
        with ClusterDeployment(net, hosts=3, transport="inprocess",
                               microbatch_size=2) as dep:
            dep.run(instances=8)
            scaler = Autoscaler(dep)
            victim = 1
            forced = ("migrate", victim, "forced for the test")
            scaler.policy.decide = lambda *a, **k: forced
            ev = scaler.poll()
            assert ev.executed and ev.event.refined is True
            hosts = dep.controller.plan.hosts()
            assert victim not in hosts and len(hosts) == 2
            out = dep.run(instances=8)
            assert float(np.asarray(out["collect"])) == seq


# ==========================================================================
# Workload schedules end to end (one seed per kind; CI's autoscale-smoke
# lane sweeps more via `python -m repro.cluster.sim --workload N`)
# ==========================================================================

class TestWorkloadScenarios:
    @pytest.mark.parametrize("kind", ["spike", "straggler", "slow-start"])
    def test_workload_kind(self, kind):
        from repro.cluster.sim import run_workload_scenario
        r = run_workload_scenario(0, kind=kind)
        assert r.ok, "\n".join(r.failures)
