"""Cluster runtime: partitioning, transports, cross-host refinement.

The paper's capstone property — the same network runs unchanged on one
machine and on a cluster — plus the §6.1.1 refinement story lifted to
deployment: the partitioned network trace-refines the unpartitioned one
(checked both directions), and every transport reproduces the sequential
oracle bit-for-bit.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cluster import (ClusterDeployment, ClusterError, CostProfile,
                           ExecConfig, InProcess, JaxMesh, MultiProcessPipe,
                           PartitionExecutor, ProcessCost, SharedMemoryRing,
                           abstract_partitioned_model, auto_assignment,
                           calibrate, check_redeployment, check_refinement,
                           cost_assignment, derive_cut_capacities,
                           make_transport, partition, repartition_without,
                           run_cluster)
from repro.core import (Collect, CombineNto1, DataParallelCollect, Emit,
                        GroupOfPipelineCollects, Network, NetworkError,
                        OnePipelineCollect, OneSeqCastList, Worker, build,
                        csp, netlog, run_sequential)
from repro.core.dataflow import Kind


def _sq(x):
    return x * x


def _inc(x):
    return x + 1.0


def _add(a, x):
    return a + x


def _mk_items(n):
    return lambda i: jnp.asarray(float(i))


def _farm(n=10, workers=3, **kw):
    return DataParallelCollect(create=_mk_items(n), function=_sq,
                               collector=_add, init=jnp.asarray(0.0),
                               workers=workers, jit_combine=True, **kw)


def _pipeline(n=7):
    return OnePipelineCollect(create=_mk_items(n), stage_ops=[_sq, _inc],
                              collector=_add, init=jnp.asarray(0.0),
                              jit_combine=True)


# module-level factory: the pipe transport's spawned hosts rebuild from this
def _farm_factory(n, workers):
    return DataParallelCollect(
        create=lambda i: jnp.asarray(float(i)),
        function=lambda x: x * x,
        collector=lambda a, x: a + x, init=jnp.asarray(0.0),
        workers=workers, jit_combine=True)


class TestPartitionPlanning:
    def test_auto_balanced_cut_farm(self):
        net = _farm()
        plan = partition(net, hosts=2)
        assert plan.hosts() == [0, 1]
        assert len(plan.cut) == 1
        (c,) = plan.cut
        assert len(net.successors(c.src)) == 1  # never cuts a fan
        # both partitions are legal GPP networks
        for h in plan.hosts():
            plan.subnetwork(h)

    def test_explicit_farm_branches_stay_with_spreader(self):
        net = _farm(9, 3, explicit=True)
        a = auto_assignment(net, 2)
        # every OneFanAny branch shares the spreader's host
        assert len({a[w] for w in net.successors("ofa")} | {a["ofa"]}) == 1

    def test_place_pins_override_auto(self):
        net = _pipeline()
        net.place("stage0", host=0).place("stage1", host=1)
        plan = partition(net, hosts=2)
        assert plan.assignment["stage0"] == 0
        assert plan.assignment["stage1"] == 1

    def test_place_validates(self):
        net = _pipeline()
        with pytest.raises(NetworkError, match="unknown process"):
            net.place("nope", host=0)
        with pytest.raises(NetworkError, match="host must be"):
            net.place("stage0", host=-1)

    def test_cyclic_host_graph_rejected(self):
        net = _pipeline()
        # emit..stage0 downstream of stage1 by host → host cycle 0<->1
        bad = {"emit": 1, "stage0": 1, "stage1": 0, "collect": 1}
        with pytest.raises(NetworkError, match="cyclic"):
            partition(net, assignment=bad)

    def test_fan_cut_rejected(self):
        net = _farm(9, 3, explicit=True)
        a = auto_assignment(net, 1)
        # split one branch off its spreader (downstream stays monotone so
        # the fan rule, not the cycle rule, must fire)
        for name in ("worker1", "afo", "collect"):
            a[name] = 1
        with pytest.raises(NetworkError, match="fans out"):
            partition(net, assignment=a)

    def test_missing_process_rejected(self):
        net = _pipeline()
        with pytest.raises(NetworkError, match="no host for"):
            partition(net, assignment={"emit": 0})

    def test_single_host_plan_has_no_cut(self):
        plan = partition(_farm(), hosts=1)
        assert plan.cut == [] and plan.hosts() == [0]


class TestCutRefinement:
    """core/csp.py across a partition cut: the partitioned model and the
    original refine each other — the paper's ``[T=`` in BOTH directions."""

    def test_farm_cut_refines_both_directions(self):
        net = _farm()
        plan = partition(net, hosts=2)
        part = abstract_partitioned_model(net, plan)
        assert csp.trace_equivalent(part, net, instances=3)  # part [T= net
        assert csp.trace_equivalent(net, part, instances=3)  # net [T= part

    def test_pipeline_cut_refines_both_directions(self):
        net = _pipeline()
        plan = partition(net, hosts=2)
        part = abstract_partitioned_model(net, plan)
        assert csp.trace_equivalent(part, net, instances=3)
        assert csp.trace_equivalent(net, part, instances=3)

    def test_check_refinement_wraps_both(self):
        net = _pipeline()
        assert check_refinement(net, partition(net, hosts=2))

    def test_relay_model_is_safe(self):
        """CSPm Definition 6 for the partitioned model itself."""
        net = _farm()
        part = abstract_partitioned_model(net, partition(net, hosts=2))
        r = csp.check(part, instances=3)
        assert r.deadlock_free and r.divergence_free
        assert r.all_paths_terminate and r.deterministic

    def test_three_way_cut_refines(self):
        net = _pipeline()
        plan = partition(net, hosts=3)
        assert len(plan.cut) >= 2
        assert check_refinement(net, plan)


class TestInProcessCluster:
    """Thread hosts, queue channels: results ≡ sequential oracle."""

    @pytest.mark.parametrize("hosts,mb", [(2, 3), (2, 4), (3, 2)])
    def test_farm_bit_identical(self, hosts, mb):
        net = _farm()
        seq = run_sequential(net, 10)["collect"]
        out = run_cluster(net, instances=10, hosts=hosts,
                          microbatch_size=mb)
        assert float(out["collect"]) == float(seq)
        assert all(r.ok for r in out.reports)

    def test_pipeline_uneven_chunks(self):
        net = _pipeline()
        seq = run_sequential(net, 7)["collect"]
        out = run_cluster(net, instances=7, hosts=2, microbatch_size=3)
        assert float(out["collect"]) == float(seq)

    def test_gop_composite(self):
        net = GroupOfPipelineCollects(
            create=_mk_items(12), stage_ops=[_sq, _inc, _inc],
            collector=_add, init=jnp.asarray(0.0), jit_combine=True,
            groups=3)
        seq = run_sequential(net, 12)["collect"]
        out = run_cluster(net, instances=12, hosts=2, microbatch_size=4)
        assert float(out["collect"]) == float(seq)

    def test_host_side_dict_collector(self):
        net = DataParallelCollect(
            create=_mk_items(5), function=_sq,
            collector=lambda acc, x: {**acc, len(acc): float(x)},
            init={}, workers=2, jit_combine=False)
        out = run_cluster(net, instances=5, hosts=2, microbatch_size=2)
        assert out["collect"] == {i: float(i * i) for i in range(5)}

    def test_combine_reducer_across_cut(self):
        """COMBINE emits nothing until its final chunk: SKIP markers keep
        the cut channel chunk-aligned."""
        vals = jnp.asarray(np.arange(12, dtype=np.float32))
        net = Network("comb")
        net.add(Emit(lambda i: vals[i], name="emit"),
                OneSeqCastList(name="cast"))
        for w in range(2):
            net.procs[f"w{w}"] = Worker(_sq if w == 0 else _inc,
                                        name=f"w{w}", tag=f"f{w}")
            net.connect("cast", f"w{w}")
        net.procs["comb"] = CombineNto1(lambda a, b: a + b, name="comb")
        net.connect("w0", "comb")
        net.connect("w1", "comb")
        net._tail = "comb"
        net.add(Collect(_add, init=jnp.asarray(0.0), jit_combine=True,
                        name="collect"))
        # cut between the combine and the collect: every chunk but the last
        # ships a SKIP marker
        assignment = {n: 0 for n in net.procs}
        assignment["collect"] = 1
        plan = partition(net, assignment=assignment)
        assert [(c.src, c.dst) for c in plan.cut] == [("comb", "collect")]
        cn = build(net)
        fused_like = cn.run_streaming(instances=12, microbatch_size=5)
        out = run_cluster(net, instances=12, plan=plan, microbatch_size=5)
        assert float(out["collect"]) == float(fused_like["collect"])

    def test_capacity_bounds_transport_queue(self):
        """ChannelDef.capacity flows across the transport: the cut channel's
        FIFO is exactly that deep (cross-host backpressure)."""
        net = Network("capped")
        net.add(Emit(_mk_items(8), name="emit"), Worker(_sq, name="w"))
        net.procs["collect"] = Collect(_add, init=jnp.asarray(0.0),
                                       jit_combine=True, name="collect")
        net.connect("w", "collect", capacity=1)
        plan = partition(net, assignment={"emit": 0, "w": 0, "collect": 1})
        t = InProcess()
        out = run_cluster(net, instances=8, plan=plan, transport=t,
                          microbatch_size=2)
        assert float(out["collect"]) == float(sum(i ** 2 for i in range(8)))
        assert t._queues[("w", "collect")].maxsize == 1

    def test_results_carry_reports(self):
        out = run_cluster(_farm(), instances=10, hosts=2, microbatch_size=5)
        assert {r.host for r in out.reports} == {0, 1}
        assert all("stream:" in r.stats_summary for r in out.reports)
        assert all("donation" in r.donation_summary for r in out.reports)


class TestDerivedCapacities:
    """Satellite: default cut-channel FIFO depth comes from the consumer
    executor's depth/lane appetite, not a blind constant, and the chosen
    values land in HostReport.capacities."""

    def test_explicit_capacity_wins(self):
        net = Network("capped")
        net.add(Emit(_mk_items(8), name="emit"), Worker(_sq, name="w"))
        net.procs["collect"] = Collect(_add, init=jnp.asarray(0.0),
                                       jit_combine=True, name="collect")
        net.connect("w", "collect", capacity=1)
        plan = partition(net, assignment={"emit": 0, "w": 0, "collect": 1})
        caps = derive_cut_capacities(plan, ExecConfig())
        assert caps[("w", "collect")] == 1

    def test_default_derived_from_depth_and_lanes(self):
        net = _farm()
        plan = partition(net, hosts=2)
        (c,) = plan.cut
        from repro.core.stream import plan_depth_lanes
        sub = plan.subnetwork(plan.assignment[c.dst])
        depth, lanes = plan_depth_lanes(sub, None, None)
        caps = derive_cut_capacities(plan, ExecConfig())
        assert caps[(c.src, c.dst)] == max(2, depth, lanes)
        # a deeper in-flight appetite widens the derived FIFO
        deep = derive_cut_capacities(plan, ExecConfig(max_in_flight=7))
        assert deep[(c.src, c.dst)] == 7

    def test_reports_and_netlog_carry_capacities(self):
        net = _farm()
        plan = partition(net, hosts=2)
        out = run_cluster(net, instances=10, plan=plan, microbatch_size=5)
        merged = {}
        for r in out.reports:
            merged.update(r.capacities)
        (c,) = plan.cut
        key = f"{c.src}->{c.dst}"
        assert key in merged and merged[key] >= 2
        rep = netlog.cluster_report(plan, out.reports)
        assert f"capacity={merged[key]}" in rep

    def test_transport_fifo_sized_to_derived(self):
        net = _farm()
        plan = partition(net, hosts=2)
        t = InProcess()
        run_cluster(net, instances=10, plan=plan, transport=t,
                    microbatch_size=5)
        (c,) = plan.cut
        caps = derive_cut_capacities(plan, ExecConfig(microbatch_size=5))
        assert t._queues[(c.src, c.dst)].maxsize == caps[(c.src, c.dst)]

    def test_fan_immediately_at_cut_boundary(self):
        """Satellite edge case: when the cut channel feeds straight into a
        work-stealing fan, the derived FIFO depth must cover the fan's full
        lane appetite, not just the channel-capacity default."""
        net = _farm(12, 4, explicit=True)  # explicit OneFanAny, 4 branches
        assignment = {n: (0 if n == "emit" else 1) for n in net.procs}
        plan = partition(net, assignment=assignment)
        (c,) = plan.cut
        assert net.procs[c.dst].kind is Kind.SPREADER  # fan AT the boundary
        caps = derive_cut_capacities(plan, ExecConfig())
        from repro.core.stream import plan_depth_lanes
        depth, lanes = plan_depth_lanes(plan.subnetwork(1), None, None)
        assert lanes == 4  # the fan defines the lane count
        assert caps[(c.src, c.dst)] == max(2, depth, lanes) >= 4
        # and the real deployment matches the oracle with that sizing
        out = run_cluster(net, instances=12, plan=plan, microbatch_size=4)
        assert float(out["collect"]) == float(
            run_sequential(net, 12)["collect"])

    def test_single_process_partitions(self):
        """Satellite edge case: one process per host — every subnet is a
        lone stage between shims, and every cut still gets the >= 2 floor."""
        net = _pipeline()
        order = net.toposort()
        plan = partition(net, assignment={n: i for i, n in enumerate(order)})
        assert len(plan.cut) == len(order) - 1
        caps = derive_cut_capacities(plan, ExecConfig())
        assert all(v >= 2 for v in caps.values())
        out = run_cluster(net, instances=7, plan=plan, microbatch_size=3)
        assert float(out["collect"]) == float(
            run_sequential(net, 7)["collect"])

    def test_capacity_floor_with_depth_one_consumer(self):
        """Satellite edge case: a consumer executor throttled to depth 1
        must still get the DEFAULT_CAPACITY floor — a 1-deep transport FIFO
        would serialise producer and consumer chunk-by-chunk."""
        from repro.cluster.transport import DEFAULT_CAPACITY
        net = _farm()
        plan = partition(net, hosts=2)
        (c,) = plan.cut
        caps = derive_cut_capacities(plan, ExecConfig(max_in_flight=1,
                                                      lanes=1))
        assert caps[(c.src, c.dst)] == DEFAULT_CAPACITY == 2

    def test_coalesced_capacity_degrades_to_uncoalesced_floor(self):
        """Satellite edge case: records larger than the coalesce budget
        ship one per slot, so the channel must get exactly the uncoalesced
        sizing ``max(floor, depth, lanes)`` — the degraded case once
        dropped the transport's floor and shrank large-record FIFOs."""
        from repro.core.stream import coalesced_capacity
        # per_slot == 1: budget smaller than one record
        assert coalesced_capacity(1, 1, record_bytes=4096,
                                  coalesce_bytes=64, floor=4) == 4
        assert coalesced_capacity(6, 3, record_bytes=4096,
                                  coalesce_bytes=64, floor=4) == 6
        # genuine coalescing still shrinks proportionally (floor unused)
        assert coalesced_capacity(8, 1, record_bytes=64,
                                  coalesce_bytes=256, floor=4) == 2

    def test_derived_capacities_floor_under_coalescing(self):
        """With coalescing on but a cut whose records exceed the budget,
        the derived FIFO must match what the per-record path would get."""
        from repro.cluster.costs import CostProfile, ProcessCost
        net = _farm()
        plan = partition(net, hosts=2)
        (c,) = plan.cut
        profile = CostProfile(costs={c.src: ProcessCost(
            name=c.src, out_bytes=1 << 20)})  # 1 MiB records
        cfg = ExecConfig(max_in_flight=1, lanes=1,
                         coalesce_bytes=1 << 10,  # far below one record
                         profile=profile)
        plain = derive_cut_capacities(plan, ExecConfig(max_in_flight=1,
                                                       lanes=1))
        assert derive_cut_capacities(plan, cfg, profile=profile) == plain


class TestClusterDeployment:
    """Tentpole: a deployment partitions, compiles, and spawns ONCE; warm
    `.run` calls reuse everything and stay bit-identical to the oracle."""

    def test_three_batches_bit_identical(self):
        net = _farm()
        with ClusterDeployment(net, hosts=2, microbatch_size=2) as dep:
            for n in (4, 6, 10):
                out = dep.run(instances=n)
                seq = run_sequential(net, n)["collect"]
                assert float(out["collect"]) == float(seq)
                assert all(r.ok for r in out.reports)

    def test_stage_jits_compile_exactly_once(self):
        """Compile-counter hook: the first batch traces every stage jit;
        same-shape warm batches must trace (and build) nothing."""
        net = _farm()
        with ClusterDeployment(net, hosts=2, microbatch_size=2) as dep:
            out1 = dep.run(instances=4)
            assert sum(r.jit_builds for r in out1.reports) > 0
            traces = {h: dict(ex.trace_counts)
                      for h, ex in dep.executors.items()}
            built = []
            for ex in dep.executors.values():
                ex.on_jit_build = built.append
            for n in (4, 6, 8):  # mb=2: every chunk shape already traced
                out = dep.run(instances=n)
                assert sum(r.jit_builds for r in out.reports) == 0
            assert built == []
            for h, ex in dep.executors.items():
                assert ex.trace_counts == traces[h]
            # a NEW chunk shape is honestly reported as a retrace even
            # though every jit cache key already exists
            out = dep.run(instances=5)  # last chunk has fresh shape (1,)
            assert sum(r.jit_builds for r in out.reports) > 0

    def test_explicit_batch_pytree(self):
        """deployment.run(batch=...) feeds the Emit an explicit batch."""
        net = _farm()
        vals = jnp.asarray(np.arange(8, dtype=np.float32) + 100.0)
        with ClusterDeployment(net, hosts=2, microbatch_size=2) as dep:
            out = dep.run(batch=vals)
            expect = float(jnp.sum(vals * vals))
            assert float(out["collect"]) == expect
            # and instance-driven batches still work on the same deployment
            seq = run_sequential(net, 6)["collect"]
            assert float(dep.run(instances=6)["collect"]) == float(seq)

    def test_failure_on_batch2_reports_then_same_deployment_recovers(self):
        """A host failure mid-deployment still yields the §8 cluster report,
        but the deployment is no longer poisoned: the next plain run()
        auto-recovers (epoch bump, drained transport) and streams a new
        batch through the SAME warm deployment.  A deterministic poison
        batch keeps failing precisely (never limps on) — recovery repairs
        hosts, not user code."""
        def tripwire(acc, x):
            if float(x) >= 16.0:
                raise RuntimeError("collector tripped")
            return {**acc, len(acc): float(x)}

        net = DataParallelCollect(create=_mk_items(8), function=_sq,
                                  collector=tripwire, init={}, workers=2,
                                  jit_combine=False)
        with ClusterDeployment(net, hosts=2, microbatch_size=2,
                               timeout_s=60) as dep:
            out = dep.run(instances=4)  # squares < 16: fine
            assert all(r.ok for r in out.reports)
            with pytest.raises(ClusterError) as ei:
                dep.run(instances=8)  # 5² = 25 trips the collector
            assert "collector tripped" in str(ei.value)
            assert "FAILED" in str(ei.value)
            # NOT poisoned: a fresh batch runs on the same deployment
            # (auto-recovery bumps the epoch first)
            out = dep.run(instances=4)
            assert out["collect"] == {i: float(i * i) for i in range(4)}
            assert dep.epoch == 2 and len(dep.events) == 1
            # the poison batch itself still fails — deterministically
            with pytest.raises(ClusterError):
                dep.run(instances=8)
            with pytest.raises(ClusterError):
                dep.recover()  # the replay trips the same user bug
            assert dep.run(instances=4)["collect"] == \
                {i: float(i * i) for i in range(4)}

    def test_closed_deployment_refuses(self):
        dep = ClusterDeployment(_farm(), hosts=2, microbatch_size=2)
        dep.close()
        with pytest.raises(NetworkError, match="closed"):
            dep.run(instances=4)

    def test_process_transport_requires_factory(self):
        """Refused before the transport allocates anything: a failed start
        must not leak shm segments or queue feeder threads (regression)."""
        for tname in ("pipe", "shm"):
            t = make_transport(tname)
            with pytest.raises(NetworkError, match="factory"):
                with ClusterDeployment(_farm(), hosts=2,
                                       transport=t) as dep:
                    dep.run(instances=4)
            if tname == "shm":
                assert not t._owned and not t._rings
            else:
                assert not t._queues

    def test_pipe_deployment_reuse_over_real_processes(self):
        net = _farm_factory(10, 3)
        with ClusterDeployment(net, hosts=2, transport="pipe",
                               microbatch_size=2,
                               factory=(_farm_factory, (10, 3))) as dep:
            for n in (4, 10):
                out = dep.run(instances=n)
                seq = run_sequential(net, n)["collect"]
                assert float(out["collect"]) == float(seq)
            warm = dep.run(instances=10)
            assert sum(r.jit_builds for r in warm.reports) == 0

    def test_shm_deployment_reuse_over_real_processes(self):
        net = _farm_factory(10, 3)
        with ClusterDeployment(net, hosts=2, transport="shm",
                               microbatch_size=2,
                               factory=(_farm_factory, (10, 3))) as dep:
            seq = run_sequential(net, 10)["collect"]
            for _ in range(2):
                out = dep.run(instances=10)
                assert float(out["collect"]) == float(seq)
            assert sum(r.jit_builds for r in out.reports) == 0


class TestFailureCapture:
    def test_worker_failure_surfaces_cross_host(self):
        def boom(x):
            raise RuntimeError("worker exploded")

        net = DataParallelCollect(create=_mk_items(4), function=boom,
                                  collector=_add, init=jnp.asarray(0.0),
                                  workers=2, jit_combine=True)
        with pytest.raises(ClusterError) as ei:
            run_cluster(net, instances=4, hosts=2, microbatch_size=2,
                        timeout_s=60)
        err = ei.value
        # the netlog cluster report carries the failing host's traceback
        assert "worker exploded" in str(err)
        assert "FAILED" in str(err)
        failed = [r for r in err.reports if not r.ok]
        assert failed and any("worker exploded" in (r.error or "")
                              for r in failed)

    def test_cluster_report_renders_ok_hosts(self):
        net = _farm()
        plan = partition(net, hosts=2)
        out = run_cluster(net, instances=10, plan=plan, microbatch_size=5)
        rep = netlog.cluster_report(plan, out.reports)
        assert "host 0 [ok]" in rep and "host 1 [ok]" in rep
        assert "channel" in rep


class TestMultiProcessPipe:
    """Real OS-process hosts (spawned interpreters): the CI-grade boundary."""

    def test_farm_bit_identical_over_real_processes(self):
        net = _farm_factory(10, 3)
        seq = run_sequential(net, 10)["collect"]
        out = run_cluster(net, instances=10, hosts=2, transport="pipe",
                          microbatch_size=3,
                          factory=(_farm_factory, (10, 3)))
        assert float(out["collect"]) == float(seq)
        assert all(r.ok for r in out.reports)

    def test_pipe_requires_factory(self):
        with pytest.raises(NetworkError, match="factory"):
            run_cluster(_farm(), instances=4, hosts=2, transport="pipe",
                        microbatch_size=2)

    def test_encode_roundtrip(self):
        from repro.cluster.transport import decode, encode
        tree = (jnp.asarray([1.0, 2.0]), {"a": jnp.arange(3)})
        enc = encode(tree)
        assert all(isinstance(l, np.ndarray)
                   for l in jax.tree_util.tree_leaves(enc))
        dec = decode(enc)
        np.testing.assert_array_equal(dec[0], np.asarray([1.0, 2.0]))

    def test_pack_raw_preserves_dtype_endianness_and_0d(self):
        """Satellite hardening: the raw header+buffer encoding that crosses
        process boundaries must round-trip dtype (byte order included),
        0-d arrays, bools, and non-contiguous views, bit-for-bit."""
        from repro.cluster.transport import _RawLeaf, pack_raw, unpack_raw
        tree = {
            "big": np.arange(6, dtype=">f4").reshape(2, 3),
            "little": np.arange(6, dtype="<i2"),
            "zerod": np.float64(3.25),
            "bool": np.asarray([True, False, True]),
            "noncontig": np.arange(12.0).reshape(3, 4).T,
            "jax": jnp.asarray([1.5, -2.5]),
            "empty": np.zeros((0, 4), np.int32),
        }
        packed = pack_raw(tree)
        # every plain leaf became a raw header+buffer record, not an array
        assert all(isinstance(l, _RawLeaf)
                   for l in jax.tree_util.tree_leaves(packed))
        dec = unpack_raw(packed)
        for k, v in tree.items():
            a = np.asarray(v)
            assert dec[k].dtype == a.dtype, k
            assert dec[k].shape == a.shape, k
            assert dec[k].tobytes() == np.ascontiguousarray(a).tobytes(), k

    def test_unpack_raw_arrays_are_writable(self):
        """The pickle path this encoding replaces handed out writable
        arrays; consumers that mutate received chunks must keep working
        (regression)."""
        from repro.cluster.transport import pack_raw, unpack_raw
        out = unpack_raw(pack_raw({"x": np.arange(4.0)}))
        out["x"] *= 2.0  # raises ValueError if read-only
        np.testing.assert_array_equal(out["x"], [0.0, 2.0, 4.0, 6.0])

    def test_pack_raw_markers_and_exotic_dtypes_pass_through(self):
        from repro.cluster.transport import EOS, SKIP, pack_raw, unpack_raw
        assert pack_raw(SKIP) == SKIP and unpack_raw(EOS) == EOS
        structured = np.zeros(2, dtype=[("a", "<f4"), ("b", "<i8")])
        packed = pack_raw(structured)  # pickle fallback keeps the array
        assert isinstance(packed, np.ndarray)
        np.testing.assert_array_equal(unpack_raw(packed), structured)

    def test_pipe_pack_roundtrip_through_endpoint(self):
        """The _pack/_unpack pair a pipe endpoint actually applies."""
        from repro.cluster.transport import _PipeEndpoint
        ep = _PipeEndpoint({})
        tree = {"x": np.arange(4, dtype=">u2"), "y": jnp.float32(7.0)}
        out = ep._unpack(ep._pack(tree))
        assert out["x"].dtype == np.dtype(">u2")
        np.testing.assert_array_equal(out["x"], np.arange(4, dtype=">u2"))
        assert np.asarray(out["y"]).shape == ()
        assert np.asarray(out["y"]).dtype == np.float32

    def test_encode_result_preserves_0d_and_dtype(self):
        from repro.cluster.runtime import _encode_result
        out = _encode_result({"collect": jnp.asarray(5, jnp.int32),
                              "v": jnp.asarray([1.0, 2.0])})
        assert np.asarray(out["collect"]).shape == ()
        assert np.asarray(out["collect"]).dtype == np.int32


class TestSharedMemoryRing:
    """Zero-copy slot-ring transport: payloads cross as raw buffer writes."""

    def test_farm_bit_identical_over_shm(self):
        net = _farm_factory(10, 3)
        seq = run_sequential(net, 10)["collect"]
        out = run_cluster(net, instances=10, hosts=2, transport="shm",
                          microbatch_size=3,
                          factory=(_farm_factory, (10, 3)))
        assert float(out["collect"]) == float(seq)
        assert all(r.ok for r in out.reports)

    def test_ring_send_recv_in_process(self):
        t = SharedMemoryRing(slot_bytes=1 << 12)
        try:
            t.setup([("a", "b")], {("a", "b"): 2})
            val = {"x": np.arange(8, dtype="<f8"), "y": np.float32(7)}
            t.send(("a", "b"), 0, val)
            out = t.recv(("a", "b"), 0)
            np.testing.assert_array_equal(out["x"], val["x"])
            assert np.asarray(out["y"]).shape == ()
            # slot came back: the ring can carry more chunks than slots
            for ci in (1, 2, 3):
                t.send(("a", "b"), ci, val)
                np.testing.assert_array_equal(
                    t.recv(("a", "b"), ci)["x"], val["x"])
        finally:
            t.close()

    def test_oversize_chunk_falls_back_inline(self):
        t = SharedMemoryRing(slot_bytes=128)
        try:
            t.setup([("a", "b")], {("a", "b"): 2})
            big = np.arange(1024, dtype=np.float64)
            t.send(("a", "b"), 0, big)
            np.testing.assert_array_equal(t.recv(("a", "b"), 0), big)
        finally:
            t.close()

    def test_ring_capacity_is_slot_count(self):
        t = SharedMemoryRing(slot_bytes=1 << 10)
        try:
            t.setup([("a", "b")], {("a", "b"): 3})
            ring = t._rings[("a", "b")]
            assert len(ring.slot_names) == 3
            assert ring.data_q._maxsize == 3
        finally:
            t.close()

    def test_out_of_order_detected_and_slot_recycled(self):
        from repro.cluster.transport import TransportError
        t = SharedMemoryRing(slot_bytes=1 << 10)
        try:
            t.setup([("a", "b")], {("a", "b"): 2})
            t.send(("a", "b"), 5, np.arange(3.0))
            with pytest.raises(TransportError, match="out of order"):
                t.recv(("a", "b"), 0)
            # the offending chunk's slot went back to the ring (invariant:
            # free slots + in-flight slots == capacity, here 2 + 0)
            ring = t._rings[("a", "b")]
            assert ring.free_q.qsize() == 2
        finally:
            t.close()


def _trip_once_farm(trip_at: int, state: dict):
    """Farm whose host-side collector raises exactly once, on its
    ``trip_at``-th call ever — a transient host failure (thread hosts share
    ``state`` with the test)."""
    def coll(acc, x):
        state["n"] = state.get("n", 0) + 1
        if state["n"] == trip_at:
            raise RuntimeError("transient collector failure")
        return {**acc, len(acc): float(x)}

    return DataParallelCollect(create=_mk_items(8), function=_sq,
                               collector=coll, init={}, workers=2,
                               jit_combine=False)


class TestElasticRecovery:
    """Tentpole: a live deployment is a control plane — host failures are
    drained, repaired (restart or rebalance), epoch-stamped, re-proved, and
    the failed batch's lost chunks replayed, all without a fresh start()."""

    EXPECT8 = {i: float(i * i) for i in range(8)}

    def test_recover_replays_and_unaffected_hosts_stay_warm(self):
        state: dict = {}
        net = _trip_once_farm(trip_at=12, state=state)
        with ClusterDeployment(net, hosts=2, microbatch_size=2,
                               timeout_s=60) as dep:
            assert dep.run(instances=8)["collect"] == self.EXPECT8
            traces = {h: dict(ex.trace_counts)
                      for h, ex in dep.executors.items()}
            with pytest.raises(ClusterError):
                dep.run(instances=8)  # call 12 lands mid-batch-2
            rec = dep.recover()
            # the replayed batch is bit-identical to the oracle
            assert rec["collect"] == self.EXPECT8
            assert all(r.ok for r in rec.reports)
            # zero new stage jits anywhere: recovery reused every warm
            # executor (same shapes, same jits — compile-counter asserted)
            assert sum(r.jit_builds for r in rec.reports) == 0
            for h, ex in dep.executors.items():
                assert dict(ex.trace_counts) == traces[h]
            # epoch bumped, event recorded, refinement re-proved
            assert dep.epoch == 2 and rec.epoch == 2
            (ev,) = dep.events
            assert ev.epoch_from == 1 and ev.epoch_to == 2
            assert ev.erred == [1] and ev.refined is True
            # ... and the deployment keeps serving warm batches
            out = dep.run(instances=8)
            assert out["collect"] == self.EXPECT8
            assert sum(r.jit_builds for r in out.reports) == 0

    def test_recovery_section_in_cluster_report(self):
        state: dict = {}
        net = _trip_once_farm(trip_at=12, state=state)
        with ClusterDeployment(net, hosts=2, microbatch_size=2,
                               timeout_s=60) as dep:
            dep.run(instances=8)
            with pytest.raises(ClusterError):
                dep.run(instances=8)
            rec = dep.recover()
            rep = netlog.cluster_report(dep.plan, rec.reports,
                                        events=dep.events)
            assert "plan epoch 2" in rep
            assert "-- recovery --" in rep
            assert "epoch 1 -> 2 (restart)" in rep
            assert "refinement(epoch 2)=True" in rep

    def test_rebalance_moves_processes_onto_survivors(self):
        """recover(mode="rebalance") reuses the planner: the failed host's
        processes move to survivors, the new plan is validated and
        re-proved, and the replay runs on the new topology."""
        state: dict = {}
        net = _trip_once_farm(trip_at=12, state=state)
        with ClusterDeployment(net, hosts=2, microbatch_size=2,
                               timeout_s=60) as dep:
            assert dep.run(instances=8)["collect"] == self.EXPECT8
            old_hosts = dep.plan.hosts()
            assert old_hosts == [0, 1]
            with pytest.raises(ClusterError):
                dep.run(instances=8)
            rec = dep.recover(mode="rebalance")
            assert rec["collect"] == self.EXPECT8
            # the erred host was evacuated: its procs now live on host 0
            assert dep.plan.hosts() == [0]
            (ev,) = dep.events
            assert ev.mode == "rebalance" and ev.moved
            assert all(dst == 0 for _, dst in ev.moved.values())
            assert ev.refined is True  # epoch-2 plan [T=] original net
            # the rebalanced single-host deployment keeps serving
            assert dep.run(instances=8)["collect"] == self.EXPECT8

    def test_stalled_survivor_resumes_partial_fold(self):
        """A consumer whose producer dies mid-stream stalls with its fold
        intact (chunk-replay bookkeeping): resuming replays ONLY the lost
        chunks, and the result matches the uninterrupted oracle."""
        from repro.cluster.transport import EOS as _EOS
        net = _farm()
        plan = partition(net, hosts=2)
        (c,) = plan.cut
        consumer = plan.assignment[c.dst]
        chan = (c.src, c.dst)
        oracle = run_sequential(net, 8)["collect"]

        t = InProcess()
        t.setup([chan], {chan: 8})
        from repro.core.builder import build as _build
        ex = PartitionExecutor(_build(plan.subnetwork(consumer)), plan=plan,
                               host=consumer, endpoint=t, microbatch_size=2)
        producer_ex = PartitionExecutor(
            _build(plan.subnetwork(plan.assignment[c.src])), plan=plan,
            host=plan.assignment[c.src], endpoint=t, microbatch_size=2)
        bounds = [(0, 2), (2, 4), (4, 6), (6, 8)]
        from repro.core.builder import make_emit_batch
        batch = make_emit_batch(net, 8)
        # producer streams chunks 0..1, then "dies" (EOS on the wire)
        producer_ex.run_partition(bounds[:2], batch)
        t.send(chan, -1, _EOS)
        with pytest.raises(NetworkError):
            ex.run_partition(bounds)
        st = ex.replay_state
        assert st is not None and st.next_ci == 2
        assert ex.stats.summary()  # telemetry survives the interruption
        # "controller": bump the epoch, replay the tail from the restarted
        # producer, resume the survivor — only chunks 2..3 flow again
        t.set_epoch(2)
        producer_ex.reset_run_state()
        producer_ex.run_partition(bounds, batch, start_ci=2)
        out = ex.resume_partition()
        assert float(out["collect"]) == float(oracle)
        assert ex.stats.replays == 1 and ex.stats.resumed_at == 2

    def test_transport_epoch_and_duplicate_semantics(self):
        """Stale-epoch records and replayed duplicates are dropped; future
        epochs are a protocol error; EOS outranks ordering."""
        from repro.cluster.transport import TransportError
        t = InProcess()
        t.setup([("a", "b")], {("a", "b"): 8})
        t.send(("a", "b"), 0, "old-epoch")
        t.set_epoch(2)
        t.send(("a", "b"), 0, "dup")       # will be asked for as ci=1
        t.send(("a", "b"), 1, "current")
        # epoch-1 record dropped, ci=0 duplicate dropped, ci=1 delivered
        assert t.recv(("a", "b"), 1) == "current"
        t.epoch = 1  # consumer behind the controller: future-epoch error
        t.send(("a", "b"), 2, "future")  # sent at epoch 1...
        t.set_epoch(2)
        t._queues[("a", "b")].put((3, 2, "from-the-future"))
        with pytest.raises(TransportError, match="epoch"):
            t.recv(("a", "b"), 2)

    def test_drain_keep_and_requeue(self):
        """drain() empties the FIFOs, returning undelivered chunks for kept
        channels; requeue() re-stamps them under the new epoch so a stalled
        survivor accepts exactly what it never folded."""
        t = InProcess()
        t.setup([("a", "b"), ("c", "d")], {("a", "b"): 8, ("c", "d"): 8})
        for ci in (2, 3, 4):
            t.send(("a", "b"), ci, {"v": np.asarray([ci])})
        t.send(("c", "d"), 0, "doomed")
        drained = t.drain(keep={("a", "b")})
        assert [ci for ci, _ in drained[("a", "b")][0]] == [2, 3, 4]
        assert drained[("c", "d")] == ([], 1)
        t.set_epoch(2)
        n = t.requeue(("a", "b"), drained[("a", "b")][0])
        assert n == 3
        for ci in (2, 3, 4):  # consumer at the new epoch reads them in order
            assert int(t.recv(("a", "b"), ci)["v"][0]) == ci

    def test_shm_drain_recycles_slots(self):
        t = SharedMemoryRing(slot_bytes=1 << 10)
        try:
            t.setup([("a", "b")], {("a", "b"): 3})
            for ci in range(3):
                t.send(("a", "b"), ci, np.arange(4.0))
            drained = t.drain()  # no keep: discard everything
            assert drained[("a", "b")][1] == 3
            # every slot is back on the free ring
            assert t._rings[("a", "b")].free_q.qsize() == 3
        finally:
            t.close()

    def test_shm_atexit_unlink_registered(self):
        """Satellite: owned segments unlink from atexit, not only close()
        — a parent that dies mid-batch must not strand /dev/shm segments."""
        t = SharedMemoryRing(slot_bytes=1 << 10)
        t.setup([("a", "b")], {("a", "b"): 2})
        assert t._atexit_armed
        names = [s.name for slots in t._owned.values() for s in slots]
        t._unlink_owned()  # what atexit would run
        from multiprocessing import shared_memory
        for name in names:
            with pytest.raises(FileNotFoundError):
                shared_memory.SharedMemory(name=name)
        t.close()  # idempotent after the atexit path
        assert not t._atexit_armed

    def test_repartition_without_prefers_upstream_merge(self):
        net = _pipeline()
        net.place("emit", host=0).place("stage0", host=1)
        net.place("stage1", host=2).place("collect", host=2)
        plan = partition(net, hosts=3)
        assign = repartition_without(plan, [1])
        assert assign["stage0"] == 0  # merged into the upstream survivor
        partition(net, assignment=assign)  # validates
        assert check_redeployment(net, plan,
                                  partition(net, assignment=assign))

    def test_repartition_without_all_hosts_failed(self):
        net = _farm()
        plan = partition(net, hosts=2)
        with pytest.raises(NetworkError, match="every host failed"):
            repartition_without(plan, plan.hosts())

    def test_check_redeployment_across_plan_shapes(self):
        net = _farm()
        p2 = partition(net, hosts=2)
        for hosts in (1, 3):
            assert check_redeployment(net, p2, partition(net, hosts=hosts))

    def test_plain_run_after_failure_discards_undelivered_chunks(self):
        """Auto-recovery (run() after a failure, no replay) must DISCARD the
        failed stream's undelivered chunks rather than requeue them: a fresh
        batch's consumer expects chunk 0, and a requeued chunk 2 would trip
        the out-of-order protocol check (regression)."""
        from repro.cluster.transport import SKIP
        state: dict = {}
        net = _trip_once_farm(trip_at=12, state=state)
        with ClusterDeployment(net, hosts=2, microbatch_size=2,
                               timeout_s=60) as dep:
            assert dep.run(instances=8)["collect"] == self.EXPECT8
            with pytest.raises(ClusterError):
                dep.run(instances=8)
            # pretend the failed stream left undelivered chunks bound for a
            # stalled survivor (the kill-host scenario, made deterministic)
            ctrl = dep.controller
            (c,) = dep.plan.cut
            ctrl._kept = {(c.src, c.dst): [(2, SKIP), (3, SKIP)]}
            ctrl._stalled = {dep.plan.assignment[c.dst]: 2}
            out = dep.run(instances=8)  # auto-recovers, then runs fresh
            assert out["collect"] == self.EXPECT8
            assert dep.events[-1].requeued == {}
            assert dep.events[-1].discarded >= 2

    def test_kill_host_refused_for_thread_hosts(self):
        with ClusterDeployment(_farm(), hosts=2,
                               microbatch_size=2) as dep:
            dep.run(instances=8)
            with pytest.raises(NetworkError, match="process transports"):
                dep.kill_host(0)

    def test_recover_without_failure_refused(self):
        with ClusterDeployment(_farm(), hosts=2, microbatch_size=2) as dep:
            dep.run(instances=8)
            with pytest.raises(NetworkError, match="nothing to recover"):
                dep.recover()

    def test_pipe_kill_host_restarts_warm(self):
        """The CI elastic-smoke scenario, in-suite: SIGKILL one real host
        process mid-deployment; the survivor stalls resumably, recover()
        respawns the corpse against the warm transport, replays the lost
        batch oracle-identically, and the survivor builds ZERO new jits."""
        net = _farm_factory(10, 3)
        seq = run_sequential(net, 10)["collect"]
        with ClusterDeployment(net, hosts=2, transport="pipe",
                               microbatch_size=2, timeout_s=120,
                               factory=(_farm_factory, (10, 3))) as dep:
            out = dep.run(instances=10)
            assert float(out["collect"]) == float(seq)
            victim = dep.plan.assignment["emit"]
            survivor = next(h for h in dep.plan.hosts() if h != victim)
            # each process host reports on its OWN queue — a SIGKILL landing
            # mid-report kills the corpse holding its queue's writer lock,
            # and a shared queue would deadlock the survivor's next report
            q_before = dict(dep.controller._result_qs)
            assert len({id(q) for q in q_before.values()}) == len(q_before)
            dep.kill_host(victim)
            with pytest.raises(ClusterError) as ei:
                dep.run(instances=10)
            assert any(not r.ok and not r.stalled for r in ei.value.reports)
            rec = dep.recover()
            assert float(rec["collect"]) == float(seq)
            assert dep.epoch == 2
            by_host = {r.host: r for r in rec.reports}
            # the unaffected host replayed entirely warm
            assert by_host[survivor].jit_builds == 0
            (ev,) = dep.events
            assert ev.dead == [victim] and ev.restarted == [victim]
            assert ev.refined is True
            # the corpse's (possibly lock-bricked) queues were replaced;
            # the survivor still reports on its warm one
            assert dep.controller._result_qs[victim] is not q_before[victim]
            assert dep.controller._result_qs[survivor] is q_before[survivor]
            # and the deployment is warm again end-to-end
            out = dep.run(instances=10)
            assert float(out["collect"]) == float(seq)
            assert sum(r.jit_builds for r in out.reports) == 0


class TestJaxMesh:
    def test_farm_bit_identical_over_mesh(self):
        net = _farm()
        seq = run_sequential(net, 10)["collect"]
        out = run_cluster(net, instances=10, hosts=2, transport="jaxmesh",
                          microbatch_size=3)
        assert float(out["collect"]) == float(seq)

    def test_ingress_constraint_folds_into_stage_jit(self):
        """The ROADMAP fold: a cut channel whose consumer is a jitted stage
        places the chunk inside that stage jit (_in_spec), not eagerly."""
        net = _pipeline()
        plan = partition(net, hosts=2)
        (c,) = [c for c in plan.cut]
        consumer_host = plan.assignment[c.dst]
        sub = plan.subnetwork(consumer_host)
        mesh = jax.sharding.Mesh(np.asarray([jax.devices()[0]]), ("host",))
        cn = build(sub, mesh=mesh)
        ex = PartitionExecutor(
            cn, plan=plan, host=consumer_host,
            endpoint=InProcess(), microbatch_size=2)
        assert net.procs[c.dst].kind is Kind.WORKER
        assert c.dst in ex._in_spec

    def test_named_fan_axis_degrades_to_submesh_replication(self):
        """A deployment-mesh fan axis (axis="data") does not exist on the
        per-host submeshes; its constraint degrades to replication instead
        of crashing the host (regression)."""
        net = DataParallelCollect(create=_mk_items(8), function=_sq,
                                  collector=_add, init=jnp.asarray(0.0),
                                  workers=2, axis="data", jit_combine=True)
        seq = run_sequential(net, 8)["collect"]
        out = run_cluster(net, instances=8, hosts=2, transport="jaxmesh",
                          microbatch_size=2)
        assert float(out["collect"]) == float(seq)

    def test_unknown_transport_rejected(self):
        with pytest.raises(NetworkError, match="unknown transport"):
            make_transport("carrier-pigeon")


class TestCostPartitioning:
    """Tentpole: measured-cost planning — calibrate once, cut by TIME not
    by count, emit a perfectly ordinary PartitionPlan that faces the same
    §6.1.1 proof obligations (and hot-swaps through reconfigure)."""

    def _skewed_net(self):
        # four stages, uniform COUNT, skewed COST (stage0/stage1 heavy)
        return OnePipelineCollect(create=_mk_items(8),
                                  stage_ops=[_sq, _sq, _inc, _inc],
                                  collector=_add, init=jnp.asarray(0.0),
                                  jit_combine=True)

    def _skewed_profile(self, heavy=("stage0", "stage1")):
        costs = {name: ProcessCost(name=name, shape=(), dtype="float32",
                                   wall_s=1e-3 if name in heavy else 1e-6,
                                   out_bytes=8)
                 for name in ("emit", "stage0", "stage1", "stage2",
                              "stage3", "collect")}
        return CostProfile(costs=costs, bandwidths={"inprocess": 1e9})

    def test_cost_cut_differs_from_count_cut_and_refines(self):
        net = self._skewed_net()
        profile = self._skewed_profile()
        count_plan = partition(net, hosts=2)
        cost_plan = partition(net, assignment=cost_assignment(
            net, 2, profile, transport="inprocess"))
        a = count_plan.assignment
        assert a["stage0"] == a["stage1"]  # count piles the heavies up
        assert (cost_plan.assignment["stage0"]
                != cost_plan.assignment["stage1"])  # cost splits them 1/1
        for plan in (count_plan, cost_plan):
            assert check_refinement(net, plan)
        assert check_redeployment(net, count_plan, cost_plan)

    def test_cost_assignment_may_use_fewer_hosts(self):
        # transfer dwarfs compute: every cut costs ~1000s, so the cheapest
        # legal plan is all-on-one-host even when three are offered
        net = _pipeline()
        costs = {n: ProcessCost(name=n, shape=(), dtype="float32",
                                wall_s=1e-7, out_bytes=1 << 20)
                 for n in ("emit", "stage0", "stage1", "collect")}
        profile = CostProfile(costs=costs, bandwidths={"inprocess": 1e3})
        a = cost_assignment(net, 3, profile, transport="inprocess")
        assert len(set(a.values())) == 1
        assert check_refinement(net, partition(net, assignment=a))

    def test_calibrate_measures_every_stage(self):
        net = _pipeline()
        profile = calibrate(net, instances=4, microbatch_size=2,
                            transports=("inprocess",))
        for name in ("stage0", "stage1", "collect"):
            c = profile.costs[name]
            assert c.source == "measured"
            assert c.wall_s > 0
        assert profile.bandwidths.get("inprocess", 0) > 0
        # the json round-trip plans identically to the live profile
        rt = CostProfile.from_json(profile.to_json())
        assert (cost_assignment(net, 2, profile, transport="inprocess")
                == cost_assignment(net, 2, rt, transport="inprocess"))

    def test_hot_swap_to_cost_plan_via_reconfigure(self):
        net = self._skewed_net()
        n = 8
        seq = run_sequential(net, n)
        cost_plan = partition(net, assignment=cost_assignment(
            net, 2, self._skewed_profile(), transport="inprocess"))
        with ClusterDeployment(net, hosts=2, transport="inprocess",
                               microbatch_size=2) as dep:
            out = dep.run(instances=n)
            assert bool(out["collect"] == seq["collect"])
            ev = dep.reconfigure(plan=cost_plan)
            assert ev.mode == "reconfigure" and ev.refined is True
            assert dep.plan.assignment == cost_plan.assignment
            out2 = dep.run(instances=n)
            assert bool(out2["collect"] == seq["collect"])

    def test_coalesced_deployment_bit_identical(self):
        net = _farm(12, 3)
        seq = run_sequential(net, 12)
        with ClusterDeployment(net, hosts=2, transport="inprocess",
                               microbatch_size=2,
                               coalesce_bytes=1 << 14) as dep:
            for _ in range(2):
                out = dep.run(instances=12)
                assert bool(out["collect"] == seq["collect"])
