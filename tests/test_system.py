"""End-to-end behaviour tests for the paper's system.

The paper's headline examples, run through the *public* API: the same user
methods execute sequentially (Listing 4) and in the compiled parallel
network (Listing 3) with identical results — GPP's core promise — and the
LM-framework layers compose with the patterns library."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (AnyFanOne, Collect, CombineNto1, DataParallelCollect,
                        Emit, EmitWithLocal, ListSeqOne, Network, OneFanAny,
                        OneParCastList, OneSeqCastList, Worker, build,
                        run_sequential, verify)
from repro.core import csp

pytestmark = pytest.mark.slow  # excluded from the fast CI lane


# --------------------------------------------------------------------------
# Monte Carlo π (paper §3) — the motivating example, end to end
# --------------------------------------------------------------------------

class TestMonteCarloPi:
    ITER = 500
    INSTANCES = 64

    def _net(self, workers=4, explicit=False):
        def create(i):  # piData.createInstance
            return jnp.asarray(i, jnp.uint32)

        def within(seed):  # piData.getWithin
            pts = jax.random.uniform(jax.random.PRNGKey(seed),
                                     (self.ITER, 2))
            return jnp.sum((pts ** 2).sum(-1) <= 1.0).astype(jnp.int32)

        def collector(acc, x):  # piResults.collector
            return acc + x

        def finalise(acc):  # piResults.finalise
            return 4.0 * acc / (self.INSTANCES * self.ITER)

        return DataParallelCollect(
            create=create, function=within, collector=collector,
            init=jnp.asarray(0, jnp.int32), finalise=finalise,
            workers=workers, jit_combine=True, explicit=explicit)

    def test_sequential_equals_parallel(self):
        net = self._net()
        seq = run_sequential(net, self.INSTANCES)["collect"]
        par = build(net).run(instances=self.INSTANCES)["collect"]
        assert float(seq) == pytest.approx(float(par), abs=1e-6)
        assert abs(float(par) - 3.14159) < 0.15  # it is π-ish

    def test_worker_count_invariance(self):
        """Paper Table 1's rows all compute the same π."""
        vals = [float(build(self._net(w)).run(
            instances=self.INSTANCES)["collect"]) for w in (1, 2, 8)]
        assert len(set(vals)) == 1

    def test_formally_verified(self):
        net = self._net(workers=2, explicit=True)
        r = csp.check(net, instances=3)
        assert r.deadlock_free and r.deterministic and r.all_paths_terminate


# --------------------------------------------------------------------------
# Concordance (paper §6.1) — map-reduce pipeline over word streams
# --------------------------------------------------------------------------

class TestConcordance:
    TEXT = ("the quick brown fox jumps over the lazy dog the fox "
            "the quick dog runs").split()

    def _net(self):
        words = self.TEXT
        vocab = sorted(set(words))
        word_id = {w: i for i, w in enumerate(vocab)}
        ids = jnp.asarray([word_id[w] for w in words], jnp.int32)
        V = len(vocab)

        def create(n):  # item n = word-string length n+1 (phase 1)
            return jnp.asarray(n + 1, jnp.int32)

        def value_list(n):  # phase 2: sum of n consecutive word values
            # fixed-size output: pad with -1 beyond valid range
            L = ids.shape[0]
            csum = jnp.concatenate([jnp.zeros(1, jnp.int32),
                                    jnp.cumsum(ids)])
            idx = jnp.arange(L)
            vals = jnp.where(idx + n <= L, csum[jnp.minimum(idx + n, L)]
                             - csum[idx], -1)
            return (n, vals)

        def indices_map(item):  # phase 3: histogram of values
            n, vals = item
            hist = jnp.zeros(V * 8, jnp.int32).at[
                jnp.clip(vals, 0, V * 8 - 1)].add(
                (vals >= 0).astype(jnp.int32))
            return (n, vals, hist)

        def words_map(item):  # phase 4: count of repeated strings
            n, vals, hist = item
            repeats = jnp.sum(jnp.where(hist > 1, hist, 0))
            return (n, repeats)

        def collector(acc, item):
            n, repeats = item
            return acc + repeats

        from repro.core import OnePipelineCollect
        return OnePipelineCollect(
            create=create, stage_ops=[value_list, indices_map, words_map],
            collector=collector, init=jnp.asarray(0, jnp.int32),
            jit_combine=True, name="concordance")

    def test_pipeline_sequential_equals_parallel(self):
        net = self._net()
        seq = run_sequential(net, 3)["collect"]
        par = build(net).run(instances=3)["collect"]
        assert int(seq) == int(par)
        assert int(seq) > 0  # repeated strings exist ("the", "the quick"…)


# --------------------------------------------------------------------------
# Goldbach (paper §6.5) — two-phase network with cast + combine
# --------------------------------------------------------------------------

class TestGoldbach:
    MAXN = 60

    def _primes(self):
        sieve = np.ones(self.MAXN + 1, bool)
        sieve[:2] = False
        for p in range(2, int(self.MAXN ** 0.5) + 1):
            if sieve[p]:
                sieve[p * p::p] = False
        return np.flatnonzero(sieve)

    def test_network(self):
        primes = jnp.asarray(np.pad(self._primes(),
                                    (0, 32 - len(self._primes()))))
        n_primes = len(self._primes())

        def create(i, local):  # EmitWithLocal: chunk of the even space
            lo = 4 + 2 * (i * 8)
            return jnp.asarray(lo, jnp.int32), local

        def get_range(lo):  # each worker checks 8 evens from lo
            es = lo + 2 * jnp.arange(8)
            isp = jnp.zeros(self.MAXN * 2 + 1, bool).at[primes].set(
                jnp.arange(32) < n_primes)

            def ok(e):
                cand = jnp.arange(2, self.MAXN + 1)
                return jnp.any(isp[cand] & isp[jnp.maximum(e - cand, 0)]
                               & (cand <= e - 2) & (e <= self.MAXN))

            return jax.vmap(ok)(es) | (es > self.MAXN)

        def collector(acc, oks):
            return jnp.logical_and(acc, jnp.all(oks))

        net = Network("goldbach")
        net.add(EmitWithLocal(create, lambda: 0, name="emit"),
                OneFanAny(name="fan"),
                Worker(get_range, name="group"),
                ListSeqOne(name="merge"),
                Collect(collector, init=jnp.asarray(True),
                        jit_combine=True, name="collect"))
        verify(net)
        seq = run_sequential(net, 4)["collect"]
        par = build(net).run(instances=4)["collect"]
        assert bool(seq) and bool(par)  # conjecture holds below 60


# --------------------------------------------------------------------------
# Casts + CombineNto1 (Goldbach's prime-distribution phase, abstracted)
# --------------------------------------------------------------------------

class TestCastCombine:
    def test_cast_then_combine(self):
        """OneSeqCastList copies to 2 branch workers; CombineNto1 folds."""
        net = Network("cast")
        net.add(Emit(lambda i: jnp.asarray(float(i + 1)), name="e"),
                OneSeqCastList(name="cast"))
        net.procs["w1"] = Worker(lambda x: x * 2, name="w1", tag="w1")
        net.procs["w2"] = Worker(lambda x: x * 3, name="w2", tag="w2")
        net.connect("cast", "w1")
        net.connect("cast", "w2")
        net.procs["comb"] = CombineNto1(lambda a, b: a + b, name="comb")
        net.connect("w1", "comb")
        net.connect("w2", "comb")
        net._tail = "comb"
        net.add(Collect(lambda a, x: a + x, init=jnp.asarray(0.0),
                        jit_combine=True, name="collect"))
        verify(net)
        seq = run_sequential(net, 4)["collect"]
        # items 1..4: each contributes 2i + 3i = 5i → 5*(1+2+3+4) = 50
        assert float(seq) == 50.0
        par = build(net).run(instances=4)["collect"]
        assert float(par) == 50.0

    def test_par_cast_equivalent(self):
        for Cast in (OneSeqCastList, OneParCastList):
            net = Network("c")
            net.add(Emit(lambda i: jnp.asarray(1.0), name="e"),
                    Cast(name="cast"))
            net.procs["w1"] = Worker(lambda x: x, name="w1")
            net.procs["w2"] = Worker(lambda x: x, name="w2")
            net.connect("cast", "w1")
            net.connect("cast", "w2")
            net.procs["m"] = AnyFanOne(name="m")
            net.connect("w1", "m")
            net.connect("w2", "m")
            net._tail = "m"
            net.add(Collect(lambda a, x: a + x, init=jnp.asarray(0.0),
                            jit_combine=True, name="collect"))
            assert float(run_sequential(net, 3)["collect"]) == 6.0


# --------------------------------------------------------------------------
# LM training as a GPP network (the framework integration)
# --------------------------------------------------------------------------

class TestLMAsNetwork:
    def test_train_network_verifies_and_steps(self, key):
        from repro.configs import get_config
        from repro.data import SyntheticLM
        from repro.models import Model
        from repro.train import AdamW
        from repro.train.train_loop import as_network, make_train_step

        cfg = get_config("qwen2-0.5b", reduced=True)
        model = Model(cfg)
        opt = AdamW(lr=1e-3)
        net = as_network(model, opt)
        verify(net)  # gppBuilder accepts the training topology
        src = SyntheticLM(batch=4, seq=16, vocab=cfg.vocab)
        params = model.init(key)
        step = make_train_step(model, opt)
        p2, o2, metrics = jax.jit(step)(params, opt.init(params),
                                        src.create(0))
        assert np.isfinite(float(metrics["loss"]))
