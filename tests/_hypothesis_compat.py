"""Offline fallback for ``hypothesis`` (property-based testing).

CI and air-gapped machines may not have ``hypothesis`` installed and must
still collect and pass the suite.  When the real library is importable we
re-export it untouched; otherwise ``@given`` degrades to running the test
body over a small deterministic grid of boundary examples (min, max, and a
midpoint per strategy) and ``@settings`` becomes a no-op.

Usage in tests (replaces ``from hypothesis import ...``)::

    from _hypothesis_compat import given, settings, st
"""

from __future__ import annotations

import functools
import inspect
import itertools

try:  # pragma: no cover - exercised only when hypothesis is installed
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    _MAX_COMBOS = 12  # keep the fallback grid roughly hypothesis-example sized

    class _Strategy:
        """A strategy reduced to its boundary examples."""

        def __init__(self, examples):
            self.examples = list(dict.fromkeys(examples))

    class _Strategies:
        @staticmethod
        def integers(min_value=0, max_value=10):
            mid = (min_value + max_value) // 2
            return _Strategy([min_value, mid, max_value])

        @staticmethod
        def floats(min_value=0.0, max_value=1.0, **_kw):
            return _Strategy([min_value, (min_value + max_value) / 2.0,
                              max_value])

        @staticmethod
        def booleans():
            return _Strategy([False, True])

        @staticmethod
        def sampled_from(options):
            return _Strategy(list(options))

    st = _Strategies()

    def given(**param_strategies):
        names = list(param_strategies)
        grids = [param_strategies[n].examples for n in names]
        combos = list(itertools.product(*grids))
        if len(combos) > _MAX_COMBOS:
            stride = (len(combos) + _MAX_COMBOS - 1) // _MAX_COMBOS
            combos = combos[::stride]

        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kw):
                for combo in combos:
                    fn(*args, **dict(zip(names, combo)), **kw)

            # hide the strategy-supplied params from pytest's fixture
            # resolution (hypothesis does the same)
            sig = inspect.signature(fn)
            wrapper.__signature__ = sig.replace(parameters=[
                p for pname, p in sig.parameters.items() if pname not in names])
            del wrapper.__wrapped__
            return wrapper

        return deco

    def settings(**_kw):
        def deco(fn):
            return fn

        return deco
