"""Sharding-rule properties: for every (arch × rules × mesh shape), the
derived parameter/cache/batch specs are structurally valid — each mesh axis
used at most once per spec, every sharded dim divisible by its axis product.
This is the invariant that makes the 40-cell dry-run never hit a
DuplicateSpecError or an indivisible shard."""

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCHS, get_config
from repro.models import Model
from repro.parallel import sharding as shlib
from repro.parallel.axes import ShardingRules


class _FakeMesh:
    """Mesh stand-in: only .shape is consulted by the spec derivation."""

    def __init__(self, shape: dict):
        self.shape = shape


MESHES = [
    _FakeMesh({"data": 16, "model": 16}),
    _FakeMesh({"pod": 2, "data": 16, "model": 16}),
    _FakeMesh({"data": 4, "model": 2}),
    _FakeMesh({"stage": 4}),  # none of the param axes exist → all replicated
]

RULES = [
    ShardingRules(),
    ShardingRules(seq="model"),
    ShardingRules(d="data"),  # fsdp
    ShardingRules(heads=None, ff=None, d=("data", "model"),
                  batch=("pod", "data", "model")),  # flattened pure DP
    ShardingRules(kv_seq="model"),  # serve
]


def _check_specs(tree, specs, mesh):
    for leaf, spec in zip(jax.tree_util.tree_leaves(tree),
                          jax.tree_util.tree_leaves(
                              specs, is_leaf=lambda x: isinstance(x, P))):
        assert isinstance(spec, P)
        assert len(spec) <= leaf.ndim, (leaf.shape, spec)
        used = []
        for dim, ax in zip(leaf.shape, tuple(spec) + (None,) * leaf.ndim):
            if ax is None:
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            size = 1
            for a in axes:
                assert a in mesh.shape, f"axis {a} not in mesh"
                assert a not in used, f"axis {a} used twice in {spec}"
                used.append(a)
                size *= mesh.shape[a]
            assert dim % size == 0, (
                f"dim {dim} not divisible by {size} in {spec}")


@pytest.mark.parametrize("arch", sorted(ARCHS))
@pytest.mark.parametrize("mesh_i", range(len(MESHES)))
@pytest.mark.parametrize("rules_i", range(len(RULES)))
def test_param_specs_always_valid(arch, mesh_i, rules_i, key):
    cfg = get_config(arch)  # FULL config — the real dims matter here
    model = Model(cfg)
    params_sds = jax.eval_shape(model.init,
                                jax.ShapeDtypeStruct((2,), jnp.uint32))
    mesh, rules = MESHES[mesh_i], RULES[rules_i]
    specs = shlib.param_specs(params_sds, mesh, rules)
    _check_specs(params_sds, specs, mesh)


@pytest.mark.parametrize("arch", ["yi-34b", "mamba2-2.7b", "zamba2-1.2b",
                                  "whisper-tiny"])
@pytest.mark.parametrize("batch,seqlen", [(128, 1024), (1, 4096)])
def test_cache_specs_always_valid(arch, batch, seqlen):
    cfg = get_config(arch)
    model = Model(cfg)
    cache_sds = jax.eval_shape(lambda: model.init_cache(batch, seqlen))
    for mesh in MESHES:
        for rules in RULES:
            specs = shlib.cache_specs(cache_sds, mesh, rules)
            _check_specs(cache_sds, specs, mesh)


def test_batch_specs_fallback_on_indivisible():
    mesh = _FakeMesh({"data": 16, "model": 16})
    batch = {"tokens": jax.ShapeDtypeStruct((10, 64), jnp.int32)}  # 10 % 16
    specs = shlib.batch_specs(batch, mesh, ShardingRules())
    assert specs["tokens"] == P()
