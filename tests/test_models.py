"""Per-architecture smoke tests (assignment requirement): reduced config of
the same family, one forward/train step on CPU, output shapes + no NaNs;
plus decode-vs-forward consistency and family-specific checks."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, SHAPES_BY_NAME, applicable, get_config
from repro.models import Model

ALL_ARCHS = sorted(ARCHS)


@pytest.fixture(scope="module")
def toks(key):
    return jax.random.randint(key, (2, 24), 0, 200)


@pytest.mark.parametrize("arch", ALL_ARCHS)
class TestArchSmoke:
    def test_forward_and_train_step(self, arch, key, toks):
        cfg = get_config(arch, reduced=True)
        m = Model(cfg)
        params = m.init(key)
        logits, aux = jax.jit(m.forward)(params, toks)
        assert logits.shape == (2, 24, cfg.vocab)
        assert np.isfinite(np.asarray(logits, np.float32)).all()
        batch = {"tokens": toks, "labels": toks}
        loss, metrics = jax.jit(m.loss_fn)(params, batch)
        assert np.isfinite(float(loss))
        g = jax.grad(lambda p: m.loss_fn(p, batch)[0])(params)
        leaves = jax.tree_util.tree_leaves(g)
        assert all(np.isfinite(np.asarray(x, np.float32)).all()
                   for x in leaves)
        assert any(float(jnp.max(jnp.abs(x))) > 0 for x in leaves), \
            "gradients all zero"

    def test_decode_matches_forward(self, arch, key, toks):
        cfg = get_config(arch, reduced=True)
        m = Model(cfg)
        params = m.init(key)
        kw = {}
        if cfg.family == "audio":
            # enc-dec: pin the SAME stub frames for forward and prefill
            import jax.numpy as jnp2
            frames = jnp2.zeros((2, 6, cfg.d_model), jnp2.float32)
            full_logits, _ = jax.jit(
                lambda p, t: m.forward(p, t, frames=frames))(params, toks)
            kw["frames"] = frames
        else:
            full_logits, _ = jax.jit(m.forward)(params, toks)
        logits_p, cache = m.prefill(params, toks[:, :12], max_len=32, **kw)
        err = float(jnp.max(jnp.abs(
            logits_p[:, -1].astype(jnp.float32)
            - full_logits[:, 11].astype(jnp.float32))))
        assert err < 3e-3, f"prefill diverges from forward: {err}"
        logits_d, cache = jax.jit(m.decode_step)(params, cache,
                                                 toks[:, 12:13])
        err = float(jnp.max(jnp.abs(
            logits_d[:, -1].astype(jnp.float32)
            - full_logits[:, 12].astype(jnp.float32))))
        assert err < 3e-3, f"decode diverges from forward: {err}"


class TestFamilySpecific:
    def test_moe_aux_loss_positive(self, key, toks):
        cfg = get_config("phi3.5-moe-42b-a6.6b", reduced=True)
        m = Model(cfg)
        params = m.init(key)
        _, aux = jax.jit(m.forward)(params, toks)
        assert float(aux) > 0.0  # load-balancing loss active

    def test_deepseek_layer0_dense(self):
        from repro.models.transformer import structure
        cfg = get_config("deepseek-moe-16b")
        assert structure(cfg)[0] == ("attn", 1)
        assert structure(cfg)[1] == ("attn_moe", 27)

    def test_zamba_shared_block_is_shared(self, key):
        """The shared attention block's params appear once (weight tying)."""
        cfg = get_config("zamba2-1.2b", reduced=True)
        m = Model(cfg)
        params = m.init(key)
        assert "shared_block" in params
        from repro.models.transformer import n_shared_applications
        assert n_shared_applications(cfg) >= 1

    def test_zamba_full_structure(self):
        from repro.models.transformer import structure
        cfg = get_config("zamba2-1.2b")
        segs = structure(cfg)
        assert sum(c for k, c in segs if k == "mamba") == 38
        assert sum(1 for k, _ in segs if k == "shared_attn") == 6

    def test_mamba_attention_free(self, key):
        from repro.models.transformer import structure
        cfg = get_config("mamba2-2.7b")
        assert all(k == "mamba" for k, _ in structure(cfg))

    def test_gemma_embed_scaling(self, key):
        cfg = get_config("gemma-2b", reduced=True)
        cfg2 = dataclasses.replace(cfg, embed_scale=False)
        m1, m2 = Model(cfg), Model(cfg2)
        p = m1.init(key)
        t = jnp.zeros((1, 4), jnp.int32)
        l1, _ = m1.forward(p, t)
        l2, _ = m2.forward(p, t)
        assert float(jnp.max(jnp.abs(l1 - l2))) > 0  # scaling has effect

    def test_mrope_positions_shape(self, key):
        cfg = get_config("qwen2-vl-2b", reduced=True)
        from repro.models.transformer import _positions
        pos = _positions(cfg, jnp.zeros((2, 8), jnp.int32))
        assert pos.shape == (2, 8, 3)

    def test_glm4_partial_rotary(self):
        cfg = get_config("glm4-9b")
        assert cfg.rope_fraction == 0.5
        rot = int(cfg.hd * cfg.rope_fraction) // 2 * 2
        assert rot == cfg.hd // 2

    def test_long_500k_applicability(self):
        """DESIGN.md §Arch-applicability: only sub-quadratic archs serve
        the 524k-context shape."""
        shape = SHAPES_BY_NAME["long_500k"]
        runnable = {a for a, c in ARCHS.items() if applicable(c, shape)[0]}
        assert runnable == {"mamba2-2.7b", "zamba2-1.2b"}


class TestRaggedMoE:
    """Ragged grouped-matmul MoE ≡ dropless capacity MoE (forward + grads
    modulo the aux-loss grouping, which is per-group vs global)."""

    @pytest.mark.parametrize("arch", ["deepseek-moe-16b",
                                      "phi3.5-moe-42b-a6.6b"])
    def test_equals_dropless_capacity(self, arch, key, toks):
        cfg = get_config(arch, reduced=True)  # reduced = dropless capacity
        cfg_r = dataclasses.replace(cfg, moe_ragged=True)
        m1, m2 = Model(cfg), Model(cfg_r)
        params = m1.init(key)
        l1, _ = jax.jit(m1.forward)(params, toks)
        l2, _ = jax.jit(m2.forward)(params, toks)
        assert float(jnp.max(jnp.abs(l1 - l2))) < 1e-4
        batch = {"tokens": toks, "labels": toks}
        g1 = jax.grad(lambda p: m1.loss_fn(p, batch, aux_weight=0.0)[0])(
            params)
        g2 = jax.grad(lambda p: m2.loss_fn(p, batch, aux_weight=0.0)[0])(
            params)
        d = max(float(jnp.max(jnp.abs(a - b))) for a, b in zip(
            jax.tree_util.tree_leaves(g1), jax.tree_util.tree_leaves(g2)))
        assert d < 1e-4, f"ragged grads diverge: {d}"


class TestChunkedAttention:
    """Query-chunked attention (the XLA-level flash analogue) is exact."""

    def test_forward_identical(self, key, toks):
        cfg = get_config("gemma-2b", reduced=True)
        cfg_c = dataclasses.replace(cfg, attn_chunk=8)
        m1, m2 = Model(cfg), Model(cfg_c)
        params = m1.init(key)
        l1, _ = jax.jit(m1.forward)(params, toks)
        l2, _ = jax.jit(m2.forward)(params, toks)
        assert float(jnp.max(jnp.abs(l1 - l2))) < 1e-5

    def test_grads_identical(self, key, toks):
        cfg = get_config("qwen2-0.5b", reduced=True)
        cfg_c = dataclasses.replace(cfg, attn_chunk=8)
        m1, m2 = Model(cfg), Model(cfg_c)
        params = m1.init(key)
        batch = {"tokens": toks, "labels": toks}
        g1 = jax.grad(lambda p: m1.loss_fn(p, batch)[0])(params)
        g2 = jax.grad(lambda p: m2.loss_fn(p, batch)[0])(params)
        d = max(float(jnp.max(jnp.abs(a - b))) for a, b in zip(
            jax.tree_util.tree_leaves(g1), jax.tree_util.tree_leaves(g2)))
        assert d < 1e-5


class TestKVQuant:
    """int8 KV cache (serving §Perf lever): greedy decode unchanged."""

    def test_greedy_decode_identical(self, key):
        cfg = get_config("qwen2-0.5b", reduced=True)
        cfg_q = dataclasses.replace(cfg, kv_quant=True)
        m, mq = Model(cfg), Model(cfg_q)
        params = m.init(key)
        toks = jax.random.randint(key, (2, 8), 0, cfg.vocab)

        def gen(model, n=8):
            logits, cache = model.prefill(params, toks, max_len=32)
            dj = jax.jit(model.decode_step)
            t = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
            out = []
            for _ in range(n):
                out.append(np.asarray(t))
                logits, cache = dj(params, cache, t[:, None])
                t = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
            return np.stack(out)

        assert (gen(m) == gen(mq)).all()

    def test_cache_half_size(self):
        cfg = get_config("yi-34b")
        import dataclasses as dc
        m = Model(dc.replace(cfg, param_dtype="bfloat16"))
        mq = Model(dc.replace(cfg, param_dtype="bfloat16", kv_quant=True))
        c = jax.eval_shape(lambda: m.init_cache(2, 1024))
        cq = jax.eval_shape(lambda: mq.init_cache(2, 1024))
        size = lambda t: sum(  # noqa: E731
            x.size * x.dtype.itemsize
            for x in jax.tree_util.tree_leaves(t))
        assert size(cq) < 0.55 * size(c)


class TestAdvanceMask:
    """Continuous-batching contract: advance=False freezes a row."""

    @pytest.mark.parametrize("arch", ["qwen2-0.5b", "mamba2-2.7b",
                                      "zamba2-1.2b"])
    def test_frozen_row_unchanged(self, arch, key):
        cfg = get_config(arch, reduced=True)
        m = Model(cfg)
        params = m.init(key)
        cache = m.init_cache(2, 16)
        t = jnp.asarray([[3], [5]], jnp.int32)
        adv = jnp.asarray([True, False])
        _, c1 = m.decode_step(params, cache, t, advance=adv)
        assert int(c1["step"][0]) == 1 and int(c1["step"][1]) == 0
        # row 1 state identical to init
        def row(tree, i):
            return [np.asarray(l)[..., i, :] if False else None
                    for l in jax.tree_util.tree_leaves(tree)]
        # decoding row 1 from c1 (where only row 0 advanced) must equal
        # decoding it from the untouched initial cache
        l_after, _ = m.decode_step(params, c1, t,
                                   advance=jnp.asarray([False, True]))
        l_ref, _ = m.decode_step(params, cache, t,
                                 advance=jnp.asarray([False, True]))
        np.testing.assert_allclose(
            np.asarray(l_after[1], np.float32),
            np.asarray(l_ref[1], np.float32), rtol=2e-4, atol=2e-4)
