"""Shared fixtures.  NOTE: no XLA_FLAGS here — tests must see 1 device;
multi-device behaviour is tested via subprocesses (test_distributed.py)."""

import jax
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)
