"""Property tests of the transport layer: pack/unpack round-trips, the
epoch protocol, and drain/requeue losslessness — across all four transports.

Runs through `tests/_hypothesis_compat.py`: with hypothesis installed these
are real property sweeps; offline (the CI fast lane) `@given` degrades to a
deterministic boundary grid and the tests stay green.
"""

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.cluster.transport import (InProcess, JaxMesh, MultiProcessPipe,
                                     SharedMemoryRing, _PipeEndpoint,
                                     _RawLeaf, pack_raw, unpack_raw)

_DTYPES = ["<f4", ">f4", "<f8", ">f8", "<i2", ">i2", "<i8", ">i8",
           "<u4", ">u4", "uint8", "bool"]
_SHAPES = [(), (1,), (3,), (0,), (2, 3), (0, 4), (4, 1, 2)]


def _make_array(dtype: str, shape: tuple, seed: int) -> np.ndarray:
    rng = np.random.default_rng(seed)
    n = int(np.prod(shape, dtype=np.int64)) if shape else 1
    dt = np.dtype(dtype)
    if dt == np.bool_:
        a = rng.integers(0, 2, size=n).astype(bool)
    elif dt.kind in "iu":
        a = rng.integers(0, 100, size=n).astype(dt)
    else:
        a = rng.standard_normal(n).astype(dt)
    return a.reshape(shape)


class TestPackRoundTripProperties:
    """Satellite: `MultiProcessPipe._pack`/unpack round-trips over random
    dtypes (byte order included), 0-d and empty-shape arrays."""

    @given(dtype=st.sampled_from(_DTYPES), shape=st.sampled_from(_SHAPES),
           seed=st.integers(0, 7))
    @settings(max_examples=40, deadline=None)
    def test_pack_raw_roundtrip(self, dtype, shape, seed):
        a = _make_array(dtype, shape, seed)
        packed = pack_raw({"x": a})
        assert isinstance(packed["x"], _RawLeaf)
        dec = unpack_raw(packed)["x"]
        assert dec.dtype == a.dtype
        assert dec.shape == a.shape
        assert dec.tobytes() == np.ascontiguousarray(a).tobytes()

    @given(dtype=st.sampled_from(_DTYPES), shape=st.sampled_from(_SHAPES),
           seed=st.integers(0, 7))
    @settings(max_examples=40, deadline=None)
    def test_pipe_endpoint_pack_roundtrip(self, dtype, shape, seed):
        """The exact _pack/_unpack pair a pipe endpoint applies (encode +
        raw header/buffer), property-swept."""
        ep = _PipeEndpoint({})
        a = _make_array(dtype, shape, seed)
        out = ep._unpack(ep._pack({"x": a, "nested": (a, a.T)}))
        for got, want in ((out["x"], a), (out["nested"][0], a),
                          (out["nested"][1], a.T)):
            assert got.dtype == want.dtype
            assert got.shape == want.shape
            assert got.tobytes() == np.ascontiguousarray(want).tobytes()

    @given(shape=st.sampled_from(_SHAPES), seed=st.integers(0, 7))
    @settings(max_examples=20, deadline=None)
    def test_noncontiguous_views_roundtrip(self, shape, seed):
        a = _make_array("<f8", shape, seed)
        view = a.T  # Fortran-ordered view for ndim >= 2
        dec = unpack_raw(pack_raw(view))
        assert dec.shape == view.shape
        np.testing.assert_array_equal(dec, np.ascontiguousarray(view))

    @given(nbytes=st.sampled_from([0, 8, 63, 64, 65, 256, 4096]),
           seed=st.integers(0, 3))
    @settings(max_examples=30, deadline=None)
    def test_shm_oversize_inline_fallback(self, nbytes, seed):
        """Satellite: chunks larger than slot_bytes (and empty ones) fall
        back to inline headers on SharedMemoryRing — bit-identical either
        way, and the slot ring never leaks a slot."""
        t = SharedMemoryRing(slot_bytes=64)
        try:
            t.setup([("a", "b")], {("a", "b"): 2})
            a = _make_array("<f8", (nbytes // 8,), seed)
            t.send(("a", "b"), 0, {"x": a})
            out = t.recv(("a", "b"), 0)
            assert out["x"].dtype == a.dtype and out["x"].shape == a.shape
            np.testing.assert_array_equal(out["x"], a)
            ring = t._rings[("a", "b")]
            assert ring.free_q.qsize() == 2  # every slot back on the ring
        finally:
            t.close()


def _mk_transport(kind: str):
    if kind == "inprocess":
        return InProcess()
    if kind == "pipe":
        return MultiProcessPipe()
    if kind == "shm":
        return SharedMemoryRing(slot_bytes=1 << 12)
    return JaxMesh()


def _payload(kind: str, ci: int):
    return {"v": np.full((3,), float(ci))}


def _fifo_len(t, chan) -> int:
    if isinstance(t, SharedMemoryRing):
        return t._rings[chan].data_q.qsize()
    return t._queues[chan].qsize()


def _settle(t, chan, n: int) -> None:
    """mp queues publish through a feeder thread: wait until the FIFO
    really holds ``n`` records before draining, so the model and the
    transport agree on what drain sees."""
    import time
    deadline = time.monotonic() + 5.0
    while _fifo_len(t, chan) != n and time.monotonic() < deadline:
        time.sleep(0.005)
    assert _fifo_len(t, chan) == n


_TRANSPORTS = ["inprocess", "pipe", "shm", "jaxmesh"]


class TestEpochProtocolProperty:
    """Satellite: for any interleaving of send / duplicate-send / drain /
    requeue / epoch-bump, recv never yields a stale-epoch or duplicate
    ``(epoch, ci)`` record — checked against an exact model of the FIFO,
    on all four transports."""

    @given(kind=st.sampled_from(_TRANSPORTS), seed=st.integers(0, 9))
    @settings(max_examples=40, deadline=None)
    def test_interleavings_never_deliver_stale_or_duplicate(self, kind,
                                                            seed):
        import random
        rng = random.Random(seed)
        chan = ("a", "b")
        cap = 8
        t = _mk_transport(kind)
        try:
            t.setup([chan], {chan: cap})
            pending = []      # model of the FIFO: [(epoch, ci), ...]
            send_ci = 0       # producer's next fresh chunk
            expect_ci = 0     # consumer's next expected chunk
            delivered = set()  # every (epoch, ci) recv handed out
            for _ in range(rng.randrange(8, 20)):
                op = rng.choice(("send", "send", "send", "dup", "recv",
                                 "recv", "bump", "discard"))
                if op == "send" and len(pending) < cap:
                    t.send(chan, send_ci, _payload(kind, send_ci))
                    pending.append((t.epoch, send_ci))
                    send_ci += 1
                elif op == "dup" and expect_ci > 0 and len(pending) < cap:
                    # replayed duplicate of an already-delivered chunk
                    ci = rng.randrange(expect_ci)
                    t.send(chan, ci, _payload(kind, ci))
                    pending.append((t.epoch, ci))
                elif op == "recv":
                    # deliverable iff the model, after protocol drops,
                    # holds (t.epoch, expect_ci); otherwise recv would
                    # block on the empty/stale FIFO
                    live = [(e, c) for e, c in pending
                            if e == t.epoch and c >= expect_ci]
                    if not (live and live[0][1] == expect_ci):
                        continue
                    got = t.recv(chan, expect_ci)
                    np.testing.assert_array_equal(
                        got["v"], _payload(kind, expect_ci)["v"])
                    rec = (t.epoch, expect_ci)
                    assert rec not in delivered, \
                        f"duplicate delivery {rec}"
                    delivered.add(rec)
                    # protocol consumed everything up to and incl. the hit
                    idx = pending.index(rec)
                    pending = pending[idx + 1:]
                    expect_ci += 1
                elif op in ("bump", "discard"):
                    _settle(t, chan, len(pending))
                    keep = {chan} if op == "bump" else frozenset()
                    drained = t.drain([chan], keep=keep)[chan][0]
                    want = [c for _, c in pending] if op == "bump" else []
                    assert [ci for ci, _ in drained] == want  # FIFO order
                    t.set_epoch(t.epoch + 1)
                    pending = []
                    if op == "bump" and drained:
                        n = t.requeue(chan, drained)
                        assert n == len(drained)  # capacity covers a FIFO
                        pending = [(t.epoch, ci) for ci, _ in drained]
                        # replaying from the first undelivered chunk again
                        expect_ci = min(ci for ci, _ in drained)
            # every delivery was unique per (epoch, ci) and none stale
            assert len(delivered) == len(set(delivered))
            for e, _ in delivered:
                assert e <= t.epoch
        finally:
            t.close()


class TestCoalescingProperties:
    """Tentpole: the batching fast path is protocol-invisible — same
    records in the same order, EOS and epoch bumps flush the buffer, and
    drain/requeue stay lossless over partially-coalesced state."""

    @given(kind=st.sampled_from(["pipe", "shm"]),
           dtype=st.sampled_from(_DTYPES),
           shape=st.sampled_from(_SHAPES),
           seed=st.integers(0, 5))
    @settings(max_examples=40, deadline=None)
    def test_batched_pack_roundtrip(self, kind, dtype, shape, seed):
        """Several records coalesced into ONE write/slot decode back
        bit-identical — over byte orders, 0-d and empty shapes."""
        chan = ("a", "b")
        t = _mk_transport(kind)
        t.coalesce_bytes = 1 << 12
        try:
            t.setup([chan], {chan: 8})
            arrs = [_make_array(dtype, shape, seed + i) for i in range(5)]
            for ci, a in enumerate(arrs):
                t.send(chan, ci, {"x": a, "pair": (a, a)})
            t.flush_sends()
            for ci, a in enumerate(arrs):
                got = t.recv(chan, ci)
                for g in (got["x"], got["pair"][0], got["pair"][1]):
                    assert g.dtype == a.dtype and g.shape == a.shape
                    assert (g.tobytes()
                            == np.ascontiguousarray(a).tobytes())
            assert _fifo_len(t, chan) == 0
        finally:
            t.close()

    @given(kind=st.sampled_from(_TRANSPORTS), seed=st.integers(0, 9))
    @settings(max_examples=30, deadline=None)
    def test_eos_flushes_pending(self, kind, seed):
        """EOS lands BEHIND every buffered record: the consumer sees the
        full stream, in order, then the marker — no explicit flush."""
        import random
        rng = random.Random(seed)
        chan = ("a", "b")
        t = _mk_transport(kind)
        t.coalesce_bytes = 1 << 12  # budget >> payloads: nothing
        try:                        # auto-flushes before the EOS
            t.setup([chan], {chan: 8})
            k = rng.randrange(1, 6)
            for ci in range(k):
                t.send(chan, ci, _payload(kind, ci))
            from repro.cluster.transport import EOS
            t.send(chan, k, EOS)
            for ci in range(k):
                got = t.recv(chan, ci)
                np.testing.assert_array_equal(got["v"],
                                              _payload(kind, ci)["v"])
            got = t.recv(chan, k)
            assert isinstance(got, str) and got == EOS
        finally:
            t.close()

    @given(kind=st.sampled_from(_TRANSPORTS), seed=st.integers(0, 9))
    @settings(max_examples=30, deadline=None)
    def test_epoch_bump_flushes_under_old_epoch(self, kind, seed):
        """Records coalesced before an epoch bump are flushed stamped with
        the OLD epoch — the new-epoch consumer drops them as stale instead
        of mistaking them for current records."""
        import random
        rng = random.Random(seed)
        chan = ("a", "b")
        t = _mk_transport(kind)
        t.coalesce_bytes = 1 << 12
        try:
            t.setup([chan], {chan: 8})
            k = rng.randrange(1, 5)
            for ci in range(k):  # abandoned epoch-1 records, still buffered
                t.send(chan, ci, {"v": np.full((3,), -1.0)})
            assert _fifo_len(t, chan) == 0  # nothing hit the FIFO yet
            t.set_epoch(2)                  # bump flushes, stamped epoch 1
            _settle(t, chan, 1)
            for ci in range(k):             # the replay, under epoch 2
                t.send(chan, ci, _payload(kind, ci))
            t.flush_sends()
            for ci in range(k):  # stale epoch-1 batch dropped silently
                got = t.recv(chan, ci)
                np.testing.assert_array_equal(got["v"],
                                              _payload(kind, ci)["v"])
        finally:
            t.close()

    @given(kind=st.sampled_from(_TRANSPORTS), seed=st.integers(0, 9))
    @settings(max_examples=30, deadline=None)
    def test_drain_sweeps_partial_coalesce_buffer(self, kind, seed):
        """Drain sees BOTH the flushed FIFO contents and the producer's
        still-buffered partial batch — requeue then replays every record
        exactly once under the new epoch (the contiguous-prefix contract
        recovery depends on)."""
        import random
        rng = random.Random(seed)
        chan = ("a", "b")
        t = _mk_transport(kind)
        t.coalesce_bytes = 1 << 12
        try:
            t.setup([chan], {chan: 8})
            k = rng.randrange(2, 7)
            j = rng.randrange(0, k)  # flushed prefix; the rest stays local
            for ci in range(j):
                t.send(chan, ci, _payload(kind, ci))
            if j:
                t.flush_sends()
                _settle(t, chan, 1)
            for ci in range(j, k):
                t.send(chan, ci, _payload(kind, ci))
            drained = t.drain([chan], keep={chan})[chan]
            assert [ci for ci, _ in drained[0]] == list(range(k))
            assert drained[1] == 0          # losslessness: nothing dropped
            t.set_epoch(2)
            n = t.requeue(chan, drained[0])
            assert n == k
            for ci in range(k):             # exactly once, in order
                got = t.recv(chan, ci)
                np.testing.assert_array_equal(got["v"],
                                              _payload(kind, ci)["v"])
            assert _fifo_len(t, chan) == 0
        finally:
            t.close()


class TestDrainRequeueLosslessness:
    """Satellite: every undelivered chunk reappears exactly once under the
    new epoch; nothing is delivered twice, nothing is lost."""

    @given(kind=st.sampled_from(_TRANSPORTS), seed=st.integers(0, 9))
    @settings(max_examples=40, deadline=None)
    def test_undelivered_chunks_survive_exactly_once(self, kind, seed):
        import random
        rng = random.Random(seed)
        chan = ("a", "b")
        cap = rng.randrange(4, 9)
        k = rng.randrange(1, cap + 1)       # chunks sent
        j = rng.randrange(0, k + 1)         # chunks consumer folded
        t = _mk_transport(kind)
        try:
            t.setup([chan], {chan: cap})
            for ci in range(k):
                t.send(chan, ci, _payload(kind, ci))
            for ci in range(j):
                got = t.recv(chan, ci)
                np.testing.assert_array_equal(got["v"],
                                              _payload(kind, ci)["v"])
            _settle(t, chan, k - j)
            drained = t.drain([chan], keep={chan})[chan]
            assert [ci for ci, _ in drained[0]] == list(range(j, k))
            assert drained[1] == 0          # losslessness: nothing dropped
            t.set_epoch(2)
            n = t.requeue(chan, drained[0])
            assert n == k - j               # capacity covers one FIFO
            seen = []
            for ci in range(j, k):          # each reappears exactly once,
                got = t.recv(chan, ci)      # in order, under the new epoch
                np.testing.assert_array_equal(got["v"],
                                              _payload(kind, ci)["v"])
                seen.append(ci)
            assert seen == list(range(j, k))
            assert _fifo_len(t, chan) == 0  # ... and exactly once: empty
        finally:
            t.close()


class TestThreadEndpointIsolation:
    """Regression: thread transports (InProcess/JaxMesh) used to return
    ``self`` from endpoint(), so with coalescing on every host thread shared
    one ``_send_pending``/``_recv_exploded`` — a host resetting for a
    replay-from-scratch cleared a stall-resuming peer's read-ahead (records
    already off the FIFO, never replayed: silent loss), and a flush-pop
    could race a concurrent append.  Each host now gets its own
    ``_ThreadEndpoint`` over the shared FIFOs."""

    def test_endpoints_distinct_stable_share_fifos(self):
        chan = ("a", "b")
        t = InProcess()
        try:
            t.setup([chan], {chan: 4})
            ep0, ep1 = t.endpoint(0), t.endpoint(1)
            assert ep0 is not ep1 and ep0 is not t
            assert t.endpoint(0) is ep0          # stable across calls
            assert ep0._queues is t._queues      # live FIFO view
            ep0.send(chan, 0, {"v": np.arange(3.0)})
            got = ep1.recv(chan, 0)
            np.testing.assert_array_equal(got["v"], np.arange(3.0))
        finally:
            t.close()

    def test_clear_read_buffers_is_host_local(self):
        """Host 1 explodes a coalesced batch into its read-ahead and folds
        a prefix; host 2 resetting for a from-scratch replay must NOT
        destroy the remainder — those records are off the FIFO and, per the
        exactly-once invariant, are never replayed."""
        c1, c2 = ("a", "b"), ("a", "c")
        t = InProcess()
        t.coalesce_bytes = 1 << 12
        try:
            t.setup([c1, c2], {c1: 4, c2: 4})
            ep1, ep2 = t.endpoint(1), t.endpoint(2)
            for ci in range(3):
                t.send(c1, ci, {"v": np.full((3,), float(ci))})
            t.flush_sends()
            got = ep1.recv(c1, 0)   # explodes the batch: 1, 2 read ahead
            np.testing.assert_array_equal(got["v"], np.zeros(3))
            assert ep1._recv_exploded[c1]
            ep2.clear_read_buffers()  # the peer's reset ...
            for ci in (1, 2):         # ... leaves the survivor intact
                got = ep1.recv(c1, ci)
                np.testing.assert_array_equal(got["v"],
                                              np.full((3,), float(ci)))
        finally:
            t.close()

    def test_parent_drain_sweeps_endpoint_buffers(self):
        """A host thread's unflushed coalesce buffer is part of what drain
        must surface: that producer believes the records were sent."""
        chan = ("a", "b")
        t = InProcess()
        t.coalesce_bytes = 1 << 12
        try:
            t.setup([chan], {chan: 4})
            ep = t.endpoint(0)
            ep.send(chan, 0, {"v": np.arange(3.0)})  # buffered, unflushed
            assert _fifo_len(t, chan) == 0
            drained = t.drain([chan], keep={chan})[chan]
            assert [ci for ci, _ in drained[0]] == [0]
            assert drained[1] == 0
            assert not ep._send_pending              # buffer detached
        finally:
            t.close()

    def test_epoch_bump_flushes_endpoint_buffers_stale(self):
        """The controller's epoch bump is a flush barrier for EVERY host's
        buffers: endpoint records buffered before the bump ship stamped
        with the OLD epoch and are discarded as stale by the consumer."""
        chan = ("a", "b")
        t = InProcess()
        t.coalesce_bytes = 1 << 12
        try:
            t.setup([chan], {chan: 4})
            ep = t.endpoint(0)
            ep.send(chan, 0, {"v": np.full((3,), -1.0)})  # doomed record
            assert _fifo_len(t, chan) == 0
            t.set_epoch(2)
            assert not ep._send_pending      # flushed by the bump ...
            assert _fifo_len(t, chan) == 1   # ... under epoch 1
            assert ep.epoch == 2             # endpoint tracks the parent
            ep.send(chan, 0, {"v": np.arange(3.0)})
            ep.flush_sends()
            got = ep.recv(chan, 0)           # stale flush dropped silently
            np.testing.assert_array_equal(got["v"], np.arange(3.0))
        finally:
            t.close()

    def test_concurrent_host_sends_lose_nothing(self):
        """Two host threads coalescing concurrently: every record arrives
        exactly once, in order (the old shared-buffer flush-pop/append race
        could land a record in an already-detached buffer)."""
        import threading
        t = InProcess()
        t.coalesce_bytes = 200  # a handful of records per batch
        chans = [("p0", "c"), ("p1", "c")]
        n = 200
        try:
            t.setup(chans, {c: 64 for c in chans})

            def producer(h, chan):
                ep = t.endpoint(h)
                for ci in range(n):
                    ep.send(chan, ci, {"v": np.full((4,), float(ci))})
                ep.flush_sends()

            threads = [threading.Thread(target=producer, args=(h, c))
                       for h, c in enumerate(chans)]
            for th in threads:
                th.start()
            for th in threads:
                th.join()
            consumer = t.endpoint(2)
            for chan in chans:
                for ci in range(n):
                    got = consumer.recv(chan, ci)
                    np.testing.assert_array_equal(
                        got["v"], np.full((4,), float(ci)))
        finally:
            t.close()

    def test_jaxmesh_endpoint_send_places_and_roundtrips(self):
        """JaxMesh's consumer-submesh placement must survive the move to
        per-host endpoints: an endpoint send routes through the parent's
        placement hook."""
        import jax
        chan = ("a", "b")
        t = JaxMesh()
        t.coalesce_bytes = 1 << 12
        try:
            t.setup([chan], {chan: 4})
            t.bind([chan], {chan: 0}, 1)
            ep = t.endpoint(0)
            ep.send(chan, 0, {"v": np.arange(3.0)})
            ep.flush_sends()
            got = t.endpoint(1).recv(chan, 0)
            assert isinstance(got["v"], jax.Array)  # placement happened
            np.testing.assert_array_equal(np.asarray(got["v"]),
                                          np.arange(3.0))
        finally:
            t.close()

    def test_shm_coalesce_budget_clamps_to_slot_bytes(self):
        """A coalesce budget larger than slot_bytes would silently degrade
        every batch to per-record sends; the shm transport clamps it (with
        a warning) so the fast path stays engaged."""
        t = SharedMemoryRing(slot_bytes=1 << 12)
        try:
            with pytest.warns(RuntimeWarning, match="clamping"):
                t.coalesce_bytes = 1 << 13
            assert t.coalesce_bytes == 1 << 12
            t.coalesce_bytes = 256  # within the slot: no warning, kept
            assert t.coalesce_bytes == 256
        finally:
            t.close()
