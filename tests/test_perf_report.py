"""benchmarks.perf_report must render on a fresh clone: missing or
truncated BENCH_*.json artifacts become explicit "(not run)" rows, never a
crash (satellite of the elastic-control-plane PR)."""

import json
import os

import benchmarks.perf_report as pr


def test_missing_artifacts_render_not_run_rows(tmp_path, monkeypatch):
    monkeypatch.setattr(pr, "REPO_DIR", str(tmp_path))
    md = pr.bench_markdown()
    assert "(not run)" in md
    assert "BENCH_stream.json missing" in md
    assert "BENCH_cluster.json missing" in md
    # it is still a well-formed table
    assert md.splitlines()[2].startswith("| suite |")


def test_truncated_artifact_renders_unreadable_row(tmp_path, monkeypatch):
    monkeypatch.setattr(pr, "REPO_DIR", str(tmp_path))
    (tmp_path / "BENCH_cluster.json").write_text(
        '{"benchmark": "cluster", "rows":')  # interrupted mid-write
    md = pr.bench_markdown()
    assert "BENCH_cluster.json unreadable" in md


def test_empty_and_malformed_rows_tolerated(tmp_path, monkeypatch):
    monkeypatch.setattr(pr, "REPO_DIR", str(tmp_path))
    (tmp_path / "BENCH_stream.json").write_text(
        json.dumps({"benchmark": "stream", "mode": "smoke", "rows": []}))
    (tmp_path / "BENCH_cluster.json").write_text(
        json.dumps({"benchmark": "cluster", "mode": "smoke",
                    "rows": [{"name": "partial_row"},  # no us_per_call
                             "not-a-dict"]}))
    md = pr.bench_markdown()
    assert "holds no rows" in md
    assert "| partial_row | - |" in md


def test_real_artifacts_still_render(monkeypatch):
    if not os.path.exists(os.path.join(pr.REPO_DIR, "BENCH_cluster.json")):
        import pytest
        pytest.skip("no local benchmark artifacts")
    assert "cluster" in pr.bench_markdown()
